"""Sensitivity-driven CR allocator vs the uniform-CR baseline.

Starts the allocator perf trajectory: on the cached trained model, one
streaming calibration pass collects every layer's tapped statistics;
the uniform plan and the water-filled plan are then both compressed
from those SAME statistics (so their activation-weighted errors are
directly comparable), at a matched (±1%) size-weighted global CR.

Reported per method: summed err_after (the acceptance metric), the
measured global CR of both plans, the allocator's CR spread, and
wall-clock — the allocate+compress flow (probe + solve + compress, one
forward pass) against the classic layer-wise run (capture + propagate,
two forwards per layer) at the same uniform CR. Emits
experiments/benchmarks/BENCH_allocator.json.
"""
from __future__ import annotations

import time

from repro.core.allocator import measured_global_cr
from repro.core.pipeline import collect_model_stats
from repro.data import calibration_batch

from benchmarks.common import (compress_with_auto, compress_with_plan,
                               compress_with_stats, emit, trained_model)

BUDGET = 0.5
METHODS = ["wanda", "slab@iters=4"]


def run(fast: bool = False):
    methods = METHODS[:1] if fast else METHODS
    out = {"arch": None, "budget": BUDGET, "methods": {}}
    for spec in methods:
        name = spec.split("@")[0]
        template = f"*={spec}"
        uniform_plan = f"*={spec}@cr={BUDGET}" if "@" not in spec \
            else f"*={spec},cr={BUDGET}"

        cfg, params = trained_model()
        out["arch"] = cfg.name
        cal = calibration_batch(cfg.vocab, n_seq=16, seq_len=128)
        t0 = time.monotonic()
        stats = collect_model_stats(cfg, params, cal, plan=template)
        probe_s = time.monotonic() - t0

        _, _, urows, uni_s = compress_with_stats(uniform_plan, stats)
        _, _, arows, alloc_s, alloc = compress_with_auto(
            BUDGET, template, stats=stats)
        # the classic two-forwards-per-layer protocol at the same
        # uniform CR — the wall-clock baseline a user pays today
        _, _, _, classic_s = compress_with_plan(uniform_plan)

        err_u = sum(s.err_after for s in urows)
        err_a = sum(s.err_after for s in arows)
        out["methods"][name] = {
            "plan_template": template,
            "err_after_sum": {"uniform": err_u, "allocated": err_a,
                              "improvement": (err_u - err_a) / err_u},
            "global_cr": {"uniform": measured_global_cr(params, urows),
                          "allocated": measured_global_cr(params, arows)},
            "cr_spread": sorted(set(alloc.crs.values())),
            "n_groups": len(alloc.crs),
            "predicted_err_sum": alloc.predicted_err,
            "wall_s": {"probe_pass": probe_s,
                       "allocate_plus_compress": alloc_s,
                       "uniform_from_stats": uni_s,
                       "uniform_classic": classic_s},
            "calib_forwards": alloc.stats.n_forwards,
        }
    emit("BENCH_allocator", out)
    return out


def check(rows) -> bool:
    """Acceptance: allocated summed err_after <= uniform at equal (±1%)
    measured global CR, from exactly one calibration pass."""
    ok = bool(rows["methods"])
    for name, m in rows["methods"].items():
        err = m["err_after_sum"]
        cr = m["global_cr"]
        ok = ok and err["allocated"] <= err["uniform"] * (1 + 1e-6)
        ok = ok and abs(cr["allocated"] - cr["uniform"]) <= 0.01
    return ok


if __name__ == "__main__":
    rows = run()
    for name, m in rows["methods"].items():
        e, c, w = m["err_after_sum"], m["global_cr"], m["wall_s"]
        print(f"{name}: err {e['uniform']:.4g} -> {e['allocated']:.4g} "
              f"({100 * e['improvement']:.1f}% better) at CR "
              f"{c['uniform']:.3f} vs {c['allocated']:.3f}; "
              f"alloc {w['allocate_plus_compress']:.1f}s vs classic "
              f"{w['uniform_classic']:.1f}s")
    print("allocator check:", "PASS" if check(rows) else "FAIL")
