"""Paper Table I: ppl + accuracy for Dense / SparseGPT / Wanda / SLaB at
CR in {50, 60, 70, 80}% unstructured and {2:4, 4:8} at 50%.

(+ magnitude as an extra floor baseline the paper cites via Wanda.)
"""
from __future__ import annotations

import argparse

from benchmarks.common import compress_and_eval, emit, evaluate, trained_model


def run(fast: bool = False):
    cfg, params = trained_model()
    rows = [{"method": "dense", "sparsity": "0%", **evaluate(cfg, params)}]
    crs = [0.5] if fast else [0.5, 0.6, 0.7, 0.8]
    patterns = [("2:4", 0.5)] if fast else [("4:8", 0.5), ("2:4", 0.5)]
    methods = ["sparsegpt", "wanda", "slab", "magnitude"]
    for cr in crs:
        for m in methods:
            r = compress_and_eval(m, cr, None)
            rows.append({"method": m, "sparsity": f"US({int(cr*100)}%)",
                         **r})
            print(rows[-1], flush=True)
    for pat, cr in patterns:
        for m in methods:
            r = compress_and_eval(m, cr, pat)
            rows.append({"method": m, "sparsity": f"{pat}({int(cr*100)}%)",
                         **r})
            print(rows[-1], flush=True)
    emit("table1", rows)
    return rows


def check(rows) -> bool:
    """Paper-claim direction checks: SLaB beats both baselines at every
    CR/pattern cell, and degrades gracefully at high CR."""
    by = {(r["method"], r["sparsity"]): r for r in rows}
    ok = True
    for s in {r["sparsity"] for r in rows if r["method"] == "slab"}:
        slab = by[("slab", s)]["ppl"]
        for base in ("wanda", "sparsegpt", "magnitude"):
            if (base, s) in by and slab > by[(base, s)]["ppl"]:
                ok = False
                print(f"  !! slab ppl {slab:.2f} > {base} "
                      f"{by[(base, s)]['ppl']:.2f} at {s}")
    return ok


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    rows = run(fast=args.fast)
    print("claim-direction check:", "PASS" if check(rows) else "FAIL")
