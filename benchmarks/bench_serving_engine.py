"""Continuous batching vs static batching under open-loop traffic,
plus a degraded-mode (chaos) row.

Replays ONE synthetic Poisson arrival trace (mixed prompt/output
lengths) two ways over the same weights:

  continuous  the serving engine (``repro.serving``): paged KV cache,
              mid-flight admission, chunked prefill riding along
              decode, retire-and-replace — wall-clock per-token
              latency, TTFT, and aggregate tokens/s from the engine's
              own bookkeeping.
  static      classic batched serving: requests are grouped into
              fixed batches in arrival order; a batch launches only
              after its LAST member arrives (head-of-line blocking)
              and runs ragged ``greedy_decode`` (right-padded prompts
              + per-row lengths) for the batch-max token budget, so
              short rows pad out the long ones. TTFT for every member
              is its batch's completion time minus its arrival —
              tokens only materialize when the whole batch returns.

A third pass replays the SAME trace through the engine under a fixed
``FaultPlan.chaos`` seed (pool shrink + forced NaNs + an arrival
burst — ``serving/faults.py``): the ``degraded`` row reports tok/s and
GOODPUT (finished-stream tokens/s) with per-status counts, gated by
``check()`` to >= 0.7x the fault-free engine throughput — graceful
degradation, quantified. Finished non-burst streams must still match
the fault-free token streams (replay-after-fault is token-exact), and
the block pool must come back whole (no leaks).

All paths compile outside the timed region (a warmup trace for the
engine's two step shapes, a warmup call per static batch shape), so
the comparison is steady-state serving, not compile time.

CPU caveat: absolute tokens/s is interpret-mode noise off-TPU; the
signal is the RATIO — continuous batching must beat static batching on
aggregate tokens/s (it stops paying head-of-line blocking and padding)
— plus the latency/TTFT percentile shape of the trace. Emits
experiments/benchmarks/BENCH_serving_engine.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import greedy_decode
from repro.models import lm
from repro.serving import Engine, EngineConfig, FaultPlan, Request
from repro.serving.engine import summarize

from benchmarks.common import emit

ARCH = "llama2_7b"
N_REQUESTS = 12
N_SLOTS = 4
BLOCK_SIZE = 4
PROMPT_RANGE = (6, 24)        # tokens, inclusive-exclusive
MAX_NEW_RANGE = (4, 13)
MEAN_INTERARRIVAL_S = 0.15
SEED = 0
CHAOS_SEED = 0                # the degraded row's FaultPlan seed
GOODPUT_FLOOR = 0.7           # degraded goodput >= floor * fault-free


def _trace(cfg, seed=SEED):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(N_REQUESTS):
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab, size=int(
                rng.integers(*PROMPT_RANGE))).astype(np.int32),
            max_new=int(rng.integers(*MAX_NEW_RANGE)),
            arrival=t))
        t += float(rng.exponential(MEAN_INTERARRIVAL_S))
    return reqs


def _fresh(reqs):
    return [Request(rid=r.rid, prompt=r.prompt.copy(), max_new=r.max_new,
                    arrival=r.arrival) for r in reqs]


def _engine_cfg():
    max_len = PROMPT_RANGE[1] + MAX_NEW_RANGE[1]
    from repro.serving.paged_cache import blocks_needed
    return EngineConfig(
        n_slots=N_SLOTS, block_size=BLOCK_SIZE,
        n_blocks=blocks_needed(max_len, BLOCK_SIZE) * N_SLOTS,
        max_len=max_len, prefill_chunk=8)


def _run_continuous(cfg, params, reqs, faults=None):
    eng = Engine(cfg, params, _engine_cfg())
    # warmup: compile both step shapes (chunk C and 1) off the clock
    warm = [Request(rid=-1, prompt=np.zeros(9, np.int32), max_new=3,
                    arrival=0.0)]
    eng.run(warm, clock="steps")
    t0 = time.monotonic()
    done = eng.run(reqs, clock="wall", faults=faults)
    m = summarize(done, time.monotonic() - t0)
    m["n_steps"] = eng.n_steps
    m["no_block_leak"] = (eng.sched.alloc.n_free == eng.ecfg.n_blocks
                          and not eng.sched.slots)
    return m, done


def _static_batches(reqs):
    """Fixed batches of N_SLOTS in arrival order (how a static server
    without continuous batching actually groups an online queue)."""
    ordered = sorted(reqs, key=lambda r: r.arrival)
    return [ordered[i:i + N_SLOTS]
            for i in range(0, len(ordered), N_SLOTS)]


def _pad_batch(batch):
    lens = np.array([len(r.prompt) for r in batch], np.int32)
    width = int(lens.max())
    prompts = np.zeros((len(batch), width), np.int32)
    for i, r in enumerate(batch):
        prompts[i, :lens[i]] = r.prompt
    return jnp.asarray(prompts), lens, max(r.max_new for r in batch)


def _run_static(cfg, params, reqs):
    batches = _static_batches(reqs)
    for batch in batches:                       # compile off the clock
        prompts, lens, gen = _pad_batch(batch)
        jax.block_until_ready(
            greedy_decode(cfg, params, prompts, gen, lengths=lens))

    t0 = time.monotonic()
    ttfts, n_tok, clock = [], 0, 0.0
    for batch in batches:
        # the batch cannot launch before its last member arrives
        clock = max(clock, max(r.arrival for r in batch))
        prompts, lens, gen = _pad_batch(batch)
        s0 = time.monotonic()
        out = jax.block_until_ready(
            greedy_decode(cfg, params, prompts, gen, lengths=lens))
        clock += time.monotonic() - s0
        for i, r in enumerate(batch):
            r.out = list(np.asarray(out[i][:r.max_new], np.int32))
            ttfts.append(clock - r.arrival)     # all tokens land at once
            n_tok += r.max_new
    wall = time.monotonic() - t0

    def pct(q):
        return float(np.percentile(np.asarray(ttfts), q))

    return {
        "n_requests": len(reqs),
        "n_tokens_out": n_tok,
        "n_batches": len(batches),
        "wall_s": wall,
        "served_s": clock,                      # incl. head-of-line waits
        "tokens_per_s": n_tok / clock if clock > 0 else 0.0,
        "ttft": {"p50": pct(50), "p95": pct(95), "p99": pct(99)},
    }


def run():
    cfg = configs.get(ARCH, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(SEED))
    trace = _trace(cfg)

    cont_reqs = _fresh(trace)
    cont, _ = _run_continuous(cfg, params, cont_reqs)
    stat_reqs = _fresh(trace)
    stat = _run_static(cfg, params, stat_reqs)

    # both paths must serve the same greedy streams
    by_rid = {r.rid: r for r in stat_reqs}
    streams_match = all(
        r.out == by_rid[r.rid].out for r in cont_reqs)

    # degraded mode: the same trace under a fixed chaos seed
    faults = FaultPlan.chaos(CHAOS_SEED, vocab=cfg.vocab,
                             n_rows=N_SLOTS, horizon=40)
    deg_reqs = _fresh(trace)
    deg, deg_done = _run_continuous(cfg, params, deg_reqs,
                                    faults=faults)
    deg["chaos_seed"] = CHAOS_SEED
    deg["fault_plan"] = repr(faults)
    # finished non-burst streams must replay token-exact vs fault-free
    deg["surviving_streams_match"] = all(
        r.out == by_rid[r.rid].out for r in deg_done
        if r.rid in by_rid and r.status == "finished")

    rows = {
        "arch": cfg.name,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "trace": {
            "n_requests": N_REQUESTS,
            "mean_interarrival_s": MEAN_INTERARRIVAL_S,
            "prompt_range": list(PROMPT_RANGE),
            "max_new_range": list(MAX_NEW_RANGE),
            "n_slots": N_SLOTS,
            "block_size": BLOCK_SIZE,
        },
        "streams_match": streams_match,
        "continuous": cont,
        "static": stat,
        "degraded": deg,
        "speedup_tokens_per_s": (cont["tokens_per_s"]
                                 / max(stat["tokens_per_s"], 1e-9)),
        "degraded_goodput_ratio": (deg["goodput_tokens_per_s"]
                                   / max(cont["tokens_per_s"], 1e-9)),
    }
    emit("BENCH_serving_engine", rows)
    return rows


def check(rows) -> bool:
    """Both paths emit identical token streams; every request finishes;
    continuous batching beats static batching on aggregate tokens/s
    (the whole point: no head-of-line blocking, no padding rounds).
    Under the fixed chaos seed, surviving streams stay token-exact, no
    blocks leak, and goodput holds >= GOODPUT_FLOOR of fault-free
    throughput (graceful degradation, not collapse)."""
    ok = rows["streams_match"]
    ok = ok and rows["continuous"]["n_requests"] == N_REQUESTS
    ok = ok and rows["continuous"]["n_tokens_out"] == \
        rows["static"]["n_tokens_out"] > 0
    ok = ok and rows["continuous"]["ttft"]["p50"] > 0.0
    ok = ok and rows["continuous"]["per_token_latency"]["p50"] > 0.0
    ok = ok and rows["speedup_tokens_per_s"] > 1.0
    deg = rows["degraded"]
    ok = ok and deg["surviving_streams_match"]
    ok = ok and deg["no_block_leak"]
    ok = ok and deg["statuses"].get("finished", 0) > 0
    ok = ok and rows["degraded_goodput_ratio"] >= GOODPUT_FLOOR
    return ok


if __name__ == "__main__":
    rows = run()
    c, s = rows["continuous"], rows["static"]
    print(f"continuous: {c['n_tokens_out']} tok in {c['wall_s']:.2f}s "
          f"= {c['tokens_per_s']:.1f} tok/s  "
          f"(ttft p50 {c['ttft']['p50']:.2f}s, "
          f"per-token p50 {c['per_token_latency']['p50'] * 1e3:.0f}ms, "
          f"{c['n_evictions']} evictions)")
    print(f"static:     {s['n_tokens_out']} tok in {s['served_s']:.2f}s "
          f"= {s['tokens_per_s']:.1f} tok/s  "
          f"(ttft p50 {s['ttft']['p50']:.2f}s, "
          f"{s['n_batches']} batches)")
    d = rows["degraded"]
    statuses = " ".join(f"{k}={v}" for k, v
                        in sorted(d["statuses"].items()))
    print(f"degraded:   {d['n_tokens_out']} tok in {d['wall_s']:.2f}s "
          f"= {d['tokens_per_s']:.1f} tok/s, goodput "
          f"{d['goodput_tokens_per_s']:.1f} tok/s "
          f"({rows['degraded_goodput_ratio']:.2f}x fault-free)  "
          f"[{statuses}] {d['n_evictions']} evictions")
    print(f"speedup: {rows['speedup_tokens_per_s']:.2f}x  "
          f"streams_match: {rows['streams_match']}  "
          f"surviving_match: {d['surviving_streams_match']}")
    print("serving_engine check:", "PASS" if check(rows) else "FAIL")
