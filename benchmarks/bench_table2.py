"""Paper Table II: hyper-parameter exploration — comparison-group size
and alternating-optimization iteration count (llama geometry, CR=50%)."""
from __future__ import annotations

import argparse

from benchmarks.common import compress_and_eval, emit, trained_model


def run(fast: bool = False):
    cfg, _ = trained_model()
    d_in = cfg.d_model
    rows = []
    groups = ([(1, d_in // 32), (1, 0), (16, 0)] if fast else
              [(1, d_in // 32), (1, d_in // 16), (1, 0), (16, 0), (32, 0)])
    for g in groups:
        r = compress_and_eval("slab", 0.5, None, iters=8, group=g)
        label = f"({g[0]}, {'D_in' if g[1] == 0 else g[1]})"
        rows.append({"sweep": "group", "value": label, **r})
        print(rows[-1], flush=True)
    iters = [1, 8] if fast else [1, 5, 10, 20, 30]
    for it in iters:
        r = compress_and_eval("slab", 0.5, None, iters=it)
        rows.append({"sweep": "iterations", "value": it, **r})
        print(rows[-1], flush=True)
    emit("table2", rows)
    return rows


def check(rows) -> bool:
    """Iterations trend: more iterations never much worse (paper: ppl
    5.678 -> 5.477 from 1 to 40)."""
    its = sorted([r for r in rows if r["sweep"] == "iterations"],
                 key=lambda r: r["value"])
    return its[-1]["ppl"] <= its[0]["ppl"] * 1.02


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    rows = run(fast=ap.parse_args().fast)
    print("iterations-trend check:", "PASS" if check(rows) else "FAIL")
