"""Benchmark aggregator: one entry per paper table/figure + kernels +
the roofline summary. ``python -m benchmarks.run [--fast]``.

Each job runs in its own subprocess: ~30 jit-compiled compress+eval
variants per table would otherwise accumulate compile caches past this
container's RAM.
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

# serving_engine covers continuous-vs-static AND the degraded-mode
# (chaos FaultPlan) goodput row — its check() gates both
JOBS = ["table1", "table2", "table3", "fig1", "fig3", "kernels",
        "packed_serve", "allocator", "serving_engine"]


def run_inline(name: str, fast: bool) -> bool:
    from benchmarks import (bench_allocator, bench_fig1, bench_fig3,
                            bench_kernels, bench_packed_serve,
                            bench_serving_engine, bench_table1,
                            bench_table2, bench_table3)
    jobs = {
        "table1": lambda: bench_table1.check(bench_table1.run(fast)),
        "table2": lambda: bench_table2.check(bench_table2.run(fast)),
        "table3": lambda: bench_table3.check(bench_table3.run()),
        "fig1": lambda: bench_fig1.check(bench_fig1.run()),
        "fig3": lambda: bench_fig3.check(bench_fig3.run()),
        "kernels": lambda: (bench_kernels.run(), True)[1],
        "packed_serve": lambda: bench_packed_serve.check(
            bench_packed_serve.run()),
        "allocator": lambda: bench_allocator.check(
            bench_allocator.run(fast)),
        "serving_engine": lambda: bench_serving_engine.check(
            bench_serving_engine.run()),
    }
    return bool(jobs[name]())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sweeps (CI-sized)")
    ap.add_argument("--only", type=str, default=None)
    ap.add_argument("--inline", type=str, default=None,
                    help="(internal) run one job in-process")
    args = ap.parse_args()

    if args.inline:
        ok = run_inline(args.inline, args.fast)
        sys.exit(0 if ok else 1)

    names = [args.only] if args.only else JOBS
    results = {}
    for name in names:
        t0 = time.monotonic()
        print(f"=== {name} ===", flush=True)
        cmd = [sys.executable, "-m", "benchmarks.run", "--inline", name]
        if args.fast:
            cmd.append("--fast")
        env = dict(os.environ)
        env.setdefault("PYTHONPATH", "src")
        proc = subprocess.run(cmd, env=env)
        ok = proc.returncode == 0
        results[name] = (ok, time.monotonic() - t0)
        print(f"=== {name}: {'PASS' if ok else 'FAIL'} "
              f"({results[name][1]:.1f}s) ===", flush=True)

    # roofline summary if dry-run artifacts exist
    for d in ("experiments/dryrun_final", "experiments/dryrun",
              "experiments/dryrun_baseline"):
        if os.path.isdir(d):
            from repro.launch import roofline
            rows = roofline.load_rows(d)
            if rows:
                print(f"\n=== roofline ({d}) ===")
                print(roofline.fmt_table(rows))
            break

    print("\nname,ok,seconds")
    for name, (ok, dt) in results.items():
        print(f"{name},{int(bool(ok))},{dt:.1f}")
    if not all(ok for ok, _ in results.values()):
        sys.exit(1)


if __name__ == "__main__":
    main()
