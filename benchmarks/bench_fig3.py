"""Paper Fig. 3: average Frobenius-norm difference between compressed
and original layer weights vs rank, at CR=50% — the rank 0 -> 1 cliff
that justifies the rank-1 design choice. Pure matrix-level study."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores
from repro.core.slab import SLaBConfig, slab_decompose, reconstruct
from benchmarks.common import emit, trained_model
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for

RANKS = [0, 1, 2, 4, 8, 16]


def run():
    cfg, params = trained_model()
    # activation norms from one calibration forward (first layer inputs)
    cal = jnp.asarray(calibration_batch(cfg.vocab, n_seq=8, seq_len=64))
    h = lm.embed_inputs(cfg, params, cal)
    an = scores.act_col_norms(h)

    # all attention + mlp weights of layer 0 (paper: averaged over layers)
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    mats = [lp["attn"]["wq"].T, lp["attn"]["wo"].T, lp["mlp"]["w_gate"].T,
            lp["mlp"]["w_down"].T]
    rows = []
    for r in RANKS:
        diffs = []
        for w in mats:
            w = w.astype(jnp.float32)
            a = an if w.shape[1] == an.shape[0] else None
            scfg = SLaBConfig(cr=0.5, iters=4, rank=max(r, 1),
                              include_lowrank=r > 0,
                              include_binary=r > 0)
            dec = slab_decompose(w, a, scfg)
            diffs.append(float(jnp.linalg.norm(
                w - reconstruct(dec)) / jnp.linalg.norm(w)))
        rows.append({"rank": r, "rel_fro_diff": float(np.mean(diffs))})
        print(rows[-1], flush=True)
    emit("fig3", rows)
    return rows


def check(rows) -> bool:
    """The cliff: rank 0 -> 1 is a big drop; 1 -> max is much smaller."""
    by = {r["rank"]: r["rel_fro_diff"] for r in rows}
    cliff = by[0] - by[1]
    tail = by[1] - by[max(by)]
    return cliff > 0 and (tail <= 0 or cliff > 2 * tail)


if __name__ == "__main__":
    rows = run()
    print("fig3 cliff check:", "PASS" if check(rows) else "FAIL")
