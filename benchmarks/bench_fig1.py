"""Paper Fig. 1: sparse + (signed) low-rank only — ppl vs rank at CR=50%
— shows the binary matrix is what makes low rank work (rank-r signed SVD
without W_B needs large rank; SLaB's rank-1 ⊙ binary beats it)."""
from __future__ import annotations

from repro.core.plan import plan_for_method
from repro.core.slab import SLaBConfig

from benchmarks.common import compress_with_plan, emit, evaluate

RANKS = [0, 1, 4, 16]


def run():
    rows = []
    for r in RANKS:
        scfg = SLaBConfig(cr=0.5, iters=4, include_binary=False,
                          include_lowrank=r > 0, rank=max(r, 1))
        cfg, new, _, _ = compress_with_plan(plan_for_method("slab", scfg))
        rows.append({"variant": f"sparse+lowrank r={r}",
                     **evaluate(cfg, new)})
        print(rows[-1], flush=True)
    # SLaB rank-1 with binary, for contrast
    cfg, new, _, _ = compress_with_plan(
        plan_for_method("slab", SLaBConfig(cr=0.5, iters=4)))
    rows.append({"variant": "SLaB r=1 (with W_B)", **evaluate(cfg, new)})
    print(rows[-1], flush=True)
    emit("fig1", rows)
    return rows


def check(rows) -> bool:
    slab = [r for r in rows if "W_B" in r["variant"]][0]["ppl"]
    lowrank_best = min(r["ppl"] for r in rows if "W_B" not in r["variant"])
    return slab <= lowrank_best


if __name__ == "__main__":
    rows = run()
    print("fig1 check (SLaB beats sparse+lowrank-only):",
          "PASS" if check(rows) else "FAIL")
