"""Paper Fig. 1: sparse + (signed) low-rank only — ppl vs rank at CR=50%
— shows the binary matrix is what makes low rank work (rank-r signed SVD
without W_B needs large rank; SLaB's rank-1 ⊙ binary beats it)."""
from __future__ import annotations

from repro.core.pipeline import compress_model
from repro.core.slab import SLaBConfig
from repro.data import calibration_batch

from benchmarks.common import emit, evaluate, trained_model

RANKS = [0, 1, 4, 16]


def run():
    cfg, params = trained_model()
    cal = calibration_batch(cfg.vocab, n_seq=16, seq_len=128)
    rows = []
    for r in RANKS:
        scfg = SLaBConfig(cr=0.5, iters=4, include_binary=False,
                          include_lowrank=r > 0, rank=max(r, 1))
        new, _ = compress_model(cfg, params, cal, method="slab", scfg=scfg)
        rows.append({"variant": f"sparse+lowrank r={r}",
                     **evaluate(cfg, new)})
        print(rows[-1], flush=True)
    # SLaB rank-1 with binary, for contrast
    new, _ = compress_model(cfg, params, cal, method="slab",
                            scfg=SLaBConfig(cr=0.5, iters=4))
    rows.append({"variant": "SLaB r=1 (with W_B)", **evaluate(cfg, new)})
    print(rows[-1], flush=True)
    emit("fig1", rows)
    return rows


def check(rows) -> bool:
    slab = [r for r in rows if "W_B" in r["variant"]][0]["ppl"]
    lowrank_best = min(r["ppl"] for r in rows if "W_B" not in r["variant"])
    return slab <= lowrank_best


if __name__ == "__main__":
    rows = run()
    print("fig1 check (SLaB beats sparse+lowrank-only):",
          "PASS" if check(rows) else "FAIL")
