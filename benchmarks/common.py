"""Shared benchmark harness: train one small LM once (cached), compress
with every method, evaluate ppl + zero-shot-style accuracy.

The paper evaluates HF Llama checkpoints on WikiText-2/LM-Eval. Offline
here: we train a small llama-geometry model on the synthetic corpus to
convergence-ish, and use (a) held-out perplexity as the ppl metric and
(b) next-token top-1 accuracy as the zero-shot-accuracy stand-in. The
COMPARISONS (SLaB vs Wanda vs SparseGPT vs magnitude at matched CR /
pattern) are what reproduce the paper's tables; absolute values differ
from the paper's (different model+data) and are labeled as such.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.pipeline import compress_model
from repro.core.plan import plan_for_method
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import softmax_xent

CACHE = os.path.join(os.path.dirname(__file__), "_cache")
ARCH = "llama2_7b"          # the paper's main model geometry (reduced)
TRAIN_STEPS = 300
EVAL_BATCHES = 8
EVAL_B, EVAL_S = 16, 128


@functools.lru_cache(maxsize=1)
def trained_model() -> Tuple[object, dict]:
    """Train (or load cached) the small paper-geometry LM."""
    from repro.checkpoint.manager import load_pytree, save_pytree
    cfg = configs.get(ARCH, smoke=True).with_(dtype=jnp.float32)
    ck = os.path.join(CACHE, "llama2_7b_smoke_trained")
    template = jax.eval_shape(
        lambda: lm.init(cfg, jax.random.PRNGKey(0))[0])
    if os.path.isdir(ck):
        params = load_pytree(template, ck)
        return cfg, params
    from repro.launch.train import train
    state, _ = train(ARCH, smoke=True, steps=TRAIN_STEPS, batch=32,
                     seq=128, ckpt_dir=None, lr=3e-3, log_every=50,
                     microbatches=1)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          state["params"])
    os.makedirs(CACHE, exist_ok=True)
    save_pytree(params, ck)
    return cfg, params


def evaluate(cfg, params) -> Dict[str, float]:
    """Held-out ppl + next-token accuracy."""
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    tot_nll, tot_acc, n = 0.0, 0.0, 0
    for batch in corpus.eval_batches(EVAL_BATCHES, EVAL_B, EVAL_S):
        x, y = jnp.asarray(batch["inputs"]), jnp.asarray(batch["labels"])
        logits, _ = lm.forward(cfg, params, x)
        tot_nll += float(softmax_xent(logits, y))
        tot_acc += float(jnp.mean(jnp.argmax(logits, -1) == y))
        n += 1
    return {"ppl": float(np.exp(tot_nll / n)), "acc": 100 * tot_acc / n}


def compress_with_plan(plan) -> Tuple[object, dict, list, float]:
    """Compress the cached trained model under ``plan`` (anything
    ``CompressionPlan.parse`` accepts). Returns (cfg, params, stats,
    compress_seconds) — the timer covers only the compression run, not
    model training/loading or calibration setup."""
    jax.clear_caches()      # each variant compiles fresh shapes; don't
    cfg, params = trained_model()   # accumulate executables across a sweep
    cal = calibration_batch(cfg.vocab, n_seq=16, seq_len=128)
    t0 = time.monotonic()
    new, stats = compress_model(cfg, params, cal, plan=plan)
    return cfg, new, stats, time.monotonic() - t0


def compress_with_stats(plan, stats) -> Tuple[object, dict, list, float]:
    """Compress the cached trained model from precollected
    ``ModelTapStats`` (no calibration forwards; the stats-path twin of
    ``compress_with_plan`` — both plans of an allocator comparison
    should go through here so their errors share one set of norms)."""
    jax.clear_caches()
    cfg, params = trained_model()
    t0 = time.monotonic()
    new, rows = compress_model(cfg, params, None, plan=plan, stats=stats)
    return cfg, new, rows, time.monotonic() - t0


def compress_with_auto(budget: float, template="*=slab",
                       stats=None) -> Tuple[object, dict, list, float,
                                            object]:
    """Sensitivity-allocate per-layer CRs at ``budget`` over
    ``template`` and compress — one calibration pass total (reused when
    ``stats`` is given). Returns (cfg, params, stats_rows, seconds,
    Allocation)."""
    from repro.core.allocator import allocate_plan
    jax.clear_caches()
    cfg, params = trained_model()
    cal = (None if stats is not None
           else calibration_batch(cfg.vocab, n_seq=16, seq_len=128))
    t0 = time.monotonic()
    alloc = allocate_plan(cfg, params, cal, budget=budget,
                          template=template, stats=stats)
    new, rows = compress_model(cfg, params, None, plan=alloc.plan,
                               stats=alloc.stats)
    return cfg, new, rows, time.monotonic() - t0, alloc


def compress_and_eval(method: str, cr: float, pattern: Optional[str],
                      iters: int = 8,
                      group=(1, 0)) -> Dict[str, float]:
    scfg = SLaBConfig(cr=cr, pattern=pattern, iters=iters, group=group)
    cfg, new, _, dt = compress_with_plan(plan_for_method(method, scfg))
    out = evaluate(cfg, new)
    out["compress_s"] = dt
    return out


def synthetic_pruned_packed(cfg, keep_of, skip=frozenset(), seed=0):
    """Pack a model from synthetic magnitude-pruned sparse-only decs —
    no calibration pipeline, so deep models build in milliseconds.
    ``keep_of(l)`` sets the per-layer keep fraction: different keeps
    give different realized ELL K_max, i.e. different packed
    signatures, i.e. scan-segment boundaries. ``skip`` (layer, path)
    pairs stay dense (partial coverage). Returns (dense_equivalent,
    packed, PackReport). Shared by bench_packed_serve and
    tests/test_segmented_scan.py."""
    from repro.core.packed_model import pack_plan_decs
    from repro.core.pipeline import _get, _set, linear_paths
    from repro.core.plan import CompressionPlan
    from repro.core.slab import SLaBDecomposition
    from repro.core.sparsity import prune_mask
    params, _ = lm.init(cfg, jax.random.PRNGKey(seed))
    decs = {}
    dense_c = jax.tree.map(lambda a: a, params)
    for name in linear_paths(cfg):
        leaf = _get(params["layers"], name)
        if leaf is None or leaf.ndim != 3:
            continue
        new = []
        for l in range(cfg.n_layers):
            w = leaf[l].T
            if (l, name) in skip:
                new.append(leaf[l])
                continue
            w_s = jnp.where(prune_mask(jnp.abs(w), keep_of(l)), w, 0.0)
            decs[(l, name)] = SLaBDecomposition(
                w_s, jnp.zeros((w.shape[0], 0), jnp.float32),
                jnp.zeros((w.shape[1], 0), jnp.float32),
                jnp.zeros((0, 0), jnp.int8))
            new.append(w_s.T)
        _set(dense_c["layers"], name, jnp.stack(new))
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers,
                                 CompressionPlan.parse("*=wanda"))
    return dense_c, packed, rep


def per_layer_segments(n_layers: int):
    """The degenerate per-layer segmentation — the old unrolled path."""
    return tuple((l, l + 1) for l in range(n_layers))


def emit(table: str, rows) -> None:
    os.makedirs("experiments/benchmarks", exist_ok=True)
    path = f"experiments/benchmarks/{table}.json"
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"[{table}] -> {path}")
