"""Kernel micro-benchmarks: fused SLaB linear vs dense matmul vs naive
two-matmul decomposition, plus packed-format HBM-byte accounting.

On CPU the interpret-mode timings are NOT TPU-representative — the
meaningful outputs here are (a) correctness at bench shapes and (b) the
bytes-streamed table (the roofline input for the decode hillclimb):

  dense bf16:             16 bits/weight
  SLaB unstructured:      16·keep + 1 (bits) + rank-1 vectors
  SLaB 2:4 packed:        8·16/16 + 2 idx + 1  ≈ 11 bits/weight at 50% CR
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, slab
from repro.core.slab import SLaBConfig
from repro.kernels import ops, ref
from benchmarks.common import emit

SHAPES = [(512, 2048, 2048), (256, 4096, 4096)]


def weight_stream_bits(dec, pattern):
    d_out, d_in = dec.w_s.shape
    total = d_out * d_in
    if pattern:
        pk = packing.pack_nm(dec.w_s, *map(int, pattern.split(":")))
        sparse_bits = packing.nm_packed_bits(pk, bits=16)
    else:
        nnz = int(jnp.sum(dec.w_s != 0))
        sparse_bits = nnz * 16 + nnz * int(np.ceil(np.log2(d_in)))  # ELL
    bits = sparse_bits + total + 16 * (d_out + d_in)   # + W_B + u,v
    return bits / total


def run():
    rows = []
    for m, n, k in SHAPES:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, k), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (n, k),
                              jnp.float32) * 0.05
        for pattern in (None, "2:4"):
            dec = slab.slab_decompose(
                w, None, SLaBConfig(cr=0.5, iters=2, pattern=pattern))
            pk = packing.pack_decomposition(dec, pattern=pattern)
            got = ops.slab_linear_kernel(x, pk, bm=128, bn=128, bk=256,
                                         interpret=True)
            want = x @ slab.reconstruct(dec).T
            err = float(jnp.max(jnp.abs(got - want)))
            bits = weight_stream_bits(dec, pattern)
            rows.append({
                "shape": f"{m}x{n}x{k}",
                "pattern": pattern or "unstructured",
                "max_err_vs_dense_reconstruction": err,
                "bits_per_weight_streamed": round(bits, 2),
                "dense_bits": 16,
                "hbm_reduction": round(16 / bits, 2),
            })
            print(rows[-1], flush=True)
    emit("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
