"""Packed vs dense serving: tokens/s and bytes-per-linear, per variant.

Starts the perf trajectory for the heterogeneous packed-serving path:
a mixed-method plan (N:M SparseGPT attention, rank-4 HASSLE-free gate,
SLaB elsewhere) is compressed once, then decode throughput is measured
for the dense-equivalent weights and for the fully packed model, and
the on-HBM storage cost of every packed variant is compared against its
dense footprint.

CPU caveat: the Pallas kernels run in interpret mode here, so absolute
packed tokens/s is NOT meaningful off-TPU — the bytes-per-linear
numbers are the hardware-independent signal (they bound the roofline
win at decode), and the tokens/s columns become meaningful on a real
TPU. Emits experiments/benchmarks/BENCH_packed_serve.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.packed_model import PackedLinear, PackedStack, pack_plan_decs
from repro.core.pipeline import _get, compress_model, linear_paths
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for

from benchmarks.common import emit

ARCH = "stablelm_12b"
PLAN = ("attn.*=sparsegpt@pattern=2:4; mlp.w_gate=hassle@rank=4; "
        "*=slab")
BATCH, STEPS = 4, 8


def _decode_toks_per_s(cfg, params, batch=BATCH, steps=STEPS) -> float:
    cache = lm.init_cache(cfg, batch, steps + 1)
    dec = jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p))
    tok = jnp.zeros((batch, 1), jnp.int32)
    logits, cache = dec(cache, tok, positions_for(cfg, batch, 1))
    jax.block_until_ready(logits)                      # compile outside
    t0 = time.monotonic()
    for t in range(1, steps + 1):
        logits, cache = dec(cache, tok,
                            positions_for(cfg, batch, 1, offset=t))
    jax.block_until_ready(logits)
    return batch * steps / (time.monotonic() - t0)


def _packed_leaf_rows(leaf, dense_leaf):
    """[(variant, packed_bytes_per_linear, n_linears)] for one path."""
    n_l = dense_leaf.shape[0]
    per_dense = dense_leaf.nbytes / n_l
    if isinstance(leaf, PackedLinear):
        per = sum(a.nbytes for a in jax.tree.leaves(leaf)) / n_l
        return [(leaf.variant, per, per_dense, n_l)]
    if isinstance(leaf, PackedStack):
        rows = []
        for grp, mem in zip(leaf.groups, leaf.members):
            per = sum(a.nbytes for a in jax.tree.leaves(grp)) / len(mem)
            rows.append((grp.variant, per, per_dense, len(mem)))
        return rows
    return []


def run():
    cfg = configs.get(ARCH, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    plan = CompressionPlan.parse(PLAN, base=SLaBConfig(cr=0.5, iters=4))
    dense_c, stats, decs = compress_model(cfg, params, cal, plan=plan,
                                          keep_decompositions=True)
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers, plan)

    tok_dense = _decode_toks_per_s(cfg, dense_c)
    tok_packed = _decode_toks_per_s(cfg, packed)

    variants = {}
    for path in linear_paths(cfg):
        leaf = _get(packed["layers"], path)
        dense_leaf = _get(dense_c["layers"], path)
        for var, per, per_dense, n in _packed_leaf_rows(leaf, dense_leaf):
            agg = variants.setdefault(
                var, {"n_linears": 0, "packed_bytes": 0.0,
                      "dense_bytes": 0.0})
            agg["n_linears"] += n
            agg["packed_bytes"] += per * n
            agg["dense_bytes"] += per_dense * n
    for var, agg in variants.items():
        agg["bytes_per_linear_packed"] = agg.pop("packed_bytes") / agg["n_linears"]
        agg["bytes_per_linear_dense"] = agg.pop("dense_bytes") / agg["n_linears"]
        agg["bytes_ratio"] = (agg["bytes_per_linear_packed"]
                              / agg["bytes_per_linear_dense"])

    rows = {
        "arch": cfg.name,
        "plan": PLAN,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "n_packed": rep.n_packed,
        "dense_fallback": len(rep.fallback),
        "by_variant": rep.by_variant,
        "tokens_per_s": {"dense": tok_dense, "packed": tok_packed},
        "variants": variants,
    }
    emit("BENCH_packed_serve", rows)
    return rows


def check(rows) -> bool:
    """Every linear packs, and every N:M / low-rank variant beats its
    dense bytes (the roofline-relevant invariant)."""
    ok = rows["dense_fallback"] == 0 and rows["n_packed"] > 0
    for var, agg in rows["variants"].items():
        if var.endswith("-nm") or var in ("binlr", "lowrank"):
            ok = ok and agg["bytes_ratio"] < 1.0
    return ok


if __name__ == "__main__":
    rows = run()
    print({k: v for k, v in rows.items() if k != "variants"})
    for var, agg in sorted(rows["variants"].items()):
        print(f"  {var}: {agg['bytes_per_linear_packed']/1e3:.1f} kB/linear "
              f"vs dense {agg['bytes_per_linear_dense']/1e3:.1f} kB "
              f"({agg['bytes_ratio']:.2f}x)")
    print("packed_serve check:", "PASS" if check(rows) else "FAIL")
