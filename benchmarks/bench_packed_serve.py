"""Packed vs dense serving: tokens/s, trace cost, bytes-per-linear,
and tensor-parallel tokens/s per mesh size.

The perf trajectory for the heterogeneous packed-serving path. Four
measurements:

  1. **tokens/s** on the PR-4 smoke config (stablelm-12b-smoke, mixed
     sparsegpt/hassle/slab plan, extended with one wanda rule so a
     sparse-ell row exists at 50% unstructured sparsity): decode
     throughput for the dense-equivalent weights, the packed model on
     the segmented-scan path (default), and the same packed model
     forced through per-layer segments (the old unrolled behavior).
  2. **trace/lower wall-clock** at depth (n_layers=DEPTH, synthetic
     pruned decs, 3 signature segments): `jax.jit(...).lower()` time of
     the decode step, segmented vs unrolled — the O(#segments) vs O(L)
     compile story.
  3. **bytes-per-linear** per packed variant vs its dense footprint
     (from PackReport.bytes_by_variant). With ELL routing every variant
     of this plan beats dense bytes — the old silent >1.0x on
     slab-dense/lowrank-dense is gone.
  4. **mesh tokens/s** (``mesh_tokens_per_s``): packed decode under a
     (1, model) device mesh at model=1/2/4, measured in ONE subprocess
     with 4 fake CPU devices (planner-placed packed leaves +
     ``use_mesh``), plus the no-mesh baseline from the same process so
     the rates are comparable. On 1 physical CPU core more shards can't
     go faster — the row is a correctness-under-mesh + overhead
     tracker; the scaling story needs a real TPU.

CPU caveat: the Pallas kernels run in interpret mode here, so absolute
packed tokens/s is NOT meaningful off-TPU — the bytes and trace-cost
numbers are the hardware-independent signal, and the tokens/s columns
become meaningful on a real TPU. Emits
experiments/benchmarks/BENCH_packed_serve.json.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.core.packed_model import pack_plan_decs
from repro.core.pipeline import compress_model
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for

from benchmarks.common import (emit, per_layer_segments,
                               synthetic_pruned_packed)

ARCH = "stablelm_12b"
PLAN = ("attn.wo=wanda; attn.*=sparsegpt@pattern=2:4; "
        "mlp.w_gate=hassle@rank=4; *=slab")
BATCH, STEPS = 4, 8
DEPTH = 24                    # layer count for the trace-cost story

MOE_ARCH = "phi3_5_moe"       # the expert-packed row
MOE_PLAN = "*=slab"
MOE_EXPERT_KEYS = ("w_gate", "w_up", "w_down")


def _decode_stepper(cfg, params, segments=None, batch=BATCH, steps=STEPS):
    """Compiled decode closure + a timed-pass runner returning tok/s."""
    dec = jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p,
                                                 segments=segments))
    tok = jnp.zeros((batch, 1), jnp.int32)

    def one_pass() -> float:
        cache = lm.init_cache(cfg, batch, steps + 1)
        logits, cache = dec(cache, tok, positions_for(cfg, batch, 1))
        jax.block_until_ready(logits)                  # compile outside
        t0 = time.monotonic()
        for t in range(1, steps + 1):
            logits, cache = dec(cache, tok,
                                positions_for(cfg, batch, 1, offset=t))
        jax.block_until_ready(logits)
        return batch * steps / (time.monotonic() - t0)

    return one_pass


def _decode_toks_per_s(steppers, reps: int = 3):
    """Measure several configurations with ALTERNATING timed passes and
    take each one's best rate — this box speeds up over a process's
    lifetime, so back-to-back single passes systematically favor
    whichever configuration runs last."""
    rates = {name: 0.0 for name in steppers}
    for _ in range(reps):
        for name, one_pass in steppers.items():
            rates[name] = max(rates[name], one_pass())
    return rates


def _synthetic_packed(cfg):
    """3-segment signature layout: keep .25 below L/3, keep .5 above,
    layer-0 attn.wq left dense."""
    _, packed, rep = synthetic_pruned_packed(
        cfg, lambda l: 0.25 if l < cfg.n_layers // 3 else 0.5,
        skip={(0, "attn.wq")})
    return packed, rep


MESH_SIZES = (1, 2, 4)


def _mesh_inline():
    """(subprocess entry) Packed decode tok/s without a mesh and under
    (1, model) meshes for each MESH_SIZES — one process, alternating
    best-of passes, JSON on the last stdout line."""
    import json

    from repro.core.packed_model import merge_packed_axes
    from repro.runtime.meshctx import use_mesh
    from repro.runtime.sharding import Planner

    cfg = configs.get(ARCH, smoke=True).with_(dtype=jnp.float32)
    # homogeneous 50%-keep pruning: every linear path packs to ONE
    # stacked sparse-ell PackedLinear — the single-segment decode path
    _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
    axes = lm.param_axes(cfg)

    steppers = {"nomesh": _decode_stepper(cfg, packed)}
    for m in MESH_SIZES:
        mesh = jax.make_mesh((1, m), ("data", "model"))
        planner = Planner(mesh, cfg)
        placed = jax.device_put(
            packed, planner.tree_shardings(
                merge_packed_axes(axes, packed), packed))
        base = _decode_stepper(cfg, placed)

        def one_pass(base=base, mesh=mesh):
            with use_mesh(mesh):
                return base()

        steppers[f"model={m}"] = one_pass

    rates = _decode_toks_per_s(steppers)
    rates["devices"] = jax.device_count()
    print(json.dumps(rates))


def _mesh_toks_per_s():
    """Run ``_mesh_inline`` under 4 fake CPU devices (a subprocess so
    the fake device count never leaks into this process's runtime)."""
    import json
    import os
    import subprocess
    import sys
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(repo, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count="
                         f"{max(MESH_SIZES)}")
    out = subprocess.run(
        [sys.executable, "-c",
         "from benchmarks.bench_packed_serve import _mesh_inline; "
         "_mesh_inline()"],
        env=env, cwd=repo, capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError(f"mesh bench failed:\n{out.stderr[-4000:]}")
    return json.loads(out.stdout.strip().splitlines()[-1])


def _moe_row():
    """Expert-packed MoE vs dense: decode tok/s plus the bytes of the
    three 3-D expert leaves served by the grouped-expert kernels (the
    dense islands the expert-axis PackedStack finally packed)."""
    cfg = configs.get(MOE_ARCH, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    plan = CompressionPlan.parse(MOE_PLAN,
                                 base=SLaBConfig(cr=0.5, iters=4))
    dense_c, _, decs = compress_model(cfg, params, cal, plan=plan,
                                      keep_decompositions=True)
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers, plan)
    rates = _decode_toks_per_s({
        "dense": _decode_stepper(cfg, dense_c),
        "expert_packed": _decode_stepper(cfg, packed),
    })
    pb = sum(sum(a.nbytes
                 for a in jax.tree.leaves(packed["layers"]["moe"][k]))
             for k in MOE_EXPERT_KEYS)
    db = sum(dense_c["layers"]["moe"][k].nbytes for k in MOE_EXPERT_KEYS)
    return {
        "arch": cfg.name,
        "plan": MOE_PLAN,
        "n_packed": rep.n_packed,
        "dense_fallback": len(rep.fallback),
        "by_variant": rep.by_variant,
        "tokens_per_s": rates,
        "expert_bytes_packed": pb,
        "expert_bytes_dense": db,
        "expert_bytes_ratio": pb / db,
    }


def _lower_seconds(cfg, params, segments=None) -> float:
    cache = lm.init_cache(cfg, BATCH, 2)
    tok = jnp.zeros((BATCH, 1), jnp.int32)
    pos = positions_for(cfg, BATCH, 1)
    jax.clear_caches()     # drop warm inner-jit kernel traces: both
    t0 = time.monotonic()  # segmentations start cold, or O(L) hides
    jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p,
                                           segments=segments)
            ).lower(cache, tok, pos)
    return time.monotonic() - t0


def run():
    cfg = configs.get(ARCH, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    plan = CompressionPlan.parse(PLAN, base=SLaBConfig(cr=0.5, iters=4))
    dense_c, stats, decs = compress_model(cfg, params, cal, plan=plan,
                                          keep_decompositions=True)
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers, plan)

    rates = _decode_toks_per_s({
        "dense": _decode_stepper(cfg, dense_c),
        "packed": _decode_stepper(cfg, packed),
        "packed_unrolled": _decode_stepper(
            cfg, packed, segments=per_layer_segments(cfg.n_layers)),
    })

    variants = {}
    for var, (per_packed, per_dense) in rep.bytes_by_variant.items():
        variants[var] = {
            "n_linears": rep.by_variant[var],
            "bytes_per_linear_packed": per_packed,
            "bytes_per_linear_dense": per_dense,
            "bytes_ratio": per_packed / per_dense,
        }

    # trace/lower cost at depth: O(#segments) segmented vs O(L) unrolled
    cfg_deep = cfg.with_(n_layers=DEPTH)
    packed_deep, rep_deep = _synthetic_packed(cfg_deep)
    lower_seg = _lower_seconds(cfg_deep, packed_deep)
    lower_unr = _lower_seconds(cfg_deep, packed_deep,
                               segments=per_layer_segments(DEPTH))

    mesh_rates = _mesh_toks_per_s()
    moe = _moe_row()

    rows = {
        "arch": cfg.name,
        "plan": PLAN,
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "n_packed": rep.n_packed,
        "dense_fallback": len(rep.fallback),
        "by_variant": rep.by_variant,
        "n_segments": len(rep.segments),
        "tokens_per_s": rates,
        "mesh_tokens_per_s": mesh_rates,
        "trace_lower_s": {"n_layers": DEPTH,
                          "n_segments": len(rep_deep.segments),
                          "segmented": lower_seg,
                          "unrolled": lower_unr},
        "variants": variants,
        "moe": moe,
    }
    emit("BENCH_packed_serve", rows)
    return rows


def check(rows) -> bool:
    """Every linear packs; every byte-reducing variant (N:M, ELL,
    binlr, lowrank) actually beats its dense bytes; the segmented path
    traces faster than the per-layer unrolled equivalent at depth; a
    tokens/s row exists per mesh size and the model=1 mesh costs at
    most modest overhead over the no-mesh path (loose bound — this
    box's timings are noisy)."""
    ok = rows["dense_fallback"] == 0 and rows["n_packed"] > 0
    ok = ok and "sparse-ell" in rows["variants"]
    for var, agg in rows["variants"].items():
        if (var.endswith("-nm") or var.endswith("-ell")
                or var in ("binlr", "lowrank")):
            ok = ok and agg["bytes_ratio"] < 1.0
    tl = rows["trace_lower_s"]
    ok = ok and tl["segmented"] < tl["unrolled"]
    mesh = rows["mesh_tokens_per_s"]
    for m in MESH_SIZES:
        ok = ok and mesh.get(f"model={m}", 0.0) > 0.0
    ok = ok and mesh["model=1"] >= 0.6 * mesh["nomesh"]
    moe = rows["moe"]
    ok = ok and moe["dense_fallback"] == 0
    ok = ok and moe["expert_bytes_ratio"] < 1.0
    return ok


if __name__ == "__main__":
    rows = run()
    print({k: v for k, v in rows.items() if k not in ("variants", "moe")})
    for var, agg in sorted(rows["variants"].items()):
        print(f"  {var}: {agg['bytes_per_linear_packed']/1e3:.1f} kB/linear "
              f"vs dense {agg['bytes_per_linear_dense']/1e3:.1f} kB "
              f"({agg['bytes_ratio']:.2f}x)")
    moe = rows["moe"]
    print(f"  moe[{moe['arch']}]: expert bytes "
          f"{moe['expert_bytes_packed']/1e3:.1f} kB vs dense "
          f"{moe['expert_bytes_dense']/1e3:.1f} kB "
          f"({moe['expert_bytes_ratio']:.2f}x), "
          f"fallback={moe['dense_fallback']}")
    print("packed_serve check:", "PASS" if check(rows) else "FAIL")
