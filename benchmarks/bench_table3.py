"""Paper Table III: component ablation at 2:4 (CR=50%):
  W_S only | W_S + W_L(r=16) | W_S + factor⊙W_B | W_S + W_L⊙W_B (SLaB).
"""
from __future__ import annotations

from repro.core.plan import plan_for_method
from repro.core.slab import SLaBConfig

from benchmarks.common import compress_with_plan, emit, evaluate

VARIANTS = [
    ("W_S", SLaBConfig(cr=0.5, pattern="2:4", iters=4,
                       include_binary=False, include_lowrank=False)),
    ("W_S + W_L(r=16)", SLaBConfig(cr=0.5, pattern="2:4", iters=4,
                                   include_binary=False, rank=16)),
    ("W_S + factor*W_B", SLaBConfig(cr=0.5, pattern="2:4", iters=4,
                                    factor_mode=True)),
    ("W_S + W_L*W_B", SLaBConfig(cr=0.5, pattern="2:4", iters=4)),
]


def run():
    rows = []
    for name, scfg in VARIANTS:
        cfg, new, _, dt = compress_with_plan(plan_for_method("slab", scfg))
        r = evaluate(cfg, new)
        rows.append({"variant": name, **r, "compress_s": dt})
        print(rows[-1], flush=True)
    emit("table3", rows)
    return rows


def check(rows) -> bool:
    """Paper's ablation ordering: full SLaB >= factor-mode > W_S-only."""
    by = {r["variant"]: r for r in rows}
    return (by["W_S + W_L*W_B"]["ppl"] <= by["W_S"]["ppl"] and
            by["W_S + factor*W_B"]["ppl"] <= by["W_S"]["ppl"])


if __name__ == "__main__":
    rows = run()
    print("ablation-ordering check:", "PASS" if check(rows) else "FAIL")
