from repro.data.synthetic import (  # noqa: F401
    SyntheticCorpus, calibration_batch, host_shard)
