"""Deterministic synthetic corpus + the paper's calibration protocol.

Offline container => no C4/WikiText. The corpus is a mixture of affine
(mod-vocab) Markov chains with controllable noise: documents follow
``next = (a·cur + b + ε) mod V`` with (a, b) drawn per-document from a
small family and ε a geometric-ish small step. An LM can learn this
structure (ppl well below uniform), pruning damages it measurably, and
generation is pure-numpy fast at any vocab size.

Determinism / fault tolerance: every batch is a pure function of
(seed, step, host). After a failover the pipeline replays identically
from the restored step — no iterator state to checkpoint.

Calibration follows SparseGPT/Wanda: 128 sequences of length 2048
(the "first shard of C4" protocol, §III-A2), same sampler for every
method being compared.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

N_CHAINS = 8        # mixture size
NOISE_W = 4         # ε ∈ [0, NOISE_W)
UNIFORM_P = 0.1     # fraction of pure-noise tokens (loss floor)


def _chain_params(vocab: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = rng.choice(np.arange(1, min(vocab, 97), 2), size=N_CHAINS)
    b = rng.integers(0, vocab, size=N_CHAINS)
    return np.stack([a, b], axis=1)                     # (N_CHAINS, 2)


def _gen_tokens(vocab: int, seed: int, n_seq: int, seq_len: int,
                salt: int) -> np.ndarray:
    """(n_seq, seq_len+1) int32 — +1 so inputs/labels can be shifted."""
    rng = np.random.default_rng((seed * 0x9E3779B9 + salt) % (2 ** 63))
    chains = _chain_params(vocab, seed)
    which = rng.integers(0, N_CHAINS, size=n_seq)
    a = chains[which, 0][:, None]
    b = chains[which, 1][:, None]
    s = seq_len + 1
    eps = rng.integers(0, NOISE_W, size=(n_seq, s))
    uni = rng.random((n_seq, s)) < UNIFORM_P
    rand_tok = rng.integers(0, vocab, size=(n_seq, s))
    toks = np.empty((n_seq, s), dtype=np.int64)
    toks[:, 0] = rng.integers(0, vocab, size=n_seq)
    for t in range(1, s):
        nxt = (a[:, 0] * toks[:, t - 1] + b[:, 0] + eps[:, t]) % vocab
        toks[:, t] = np.where(uni[:, t], rand_tok[:, t], nxt)
    return toks.astype(np.int32)


class SyntheticCorpus:
    """Stateless batch source: batch(step) is deterministic."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.seed = seed

    def batch(self, step: int, batch_size: int, seq_len: int,
              host: int = 0) -> Dict[str, np.ndarray]:
        salt = step * 1_000_003 + host * 7_919 + 1
        toks = _gen_tokens(self.vocab, self.seed, batch_size, seq_len, salt)
        return {"inputs": toks[:, :-1], "labels": toks[:, 1:]}

    def eval_batches(self, n_batches: int, batch_size: int, seq_len: int):
        """Held-out split (disjoint salt space from training steps)."""
        for i in range(n_batches):
            salt = -(i + 1) * 104_729
            toks = _gen_tokens(self.vocab, self.seed, batch_size, seq_len,
                               salt)
            yield {"inputs": toks[:, :-1], "labels": toks[:, 1:]}


def host_shard(batch: Dict[str, np.ndarray], host: int, n_hosts: int
               ) -> Dict[str, np.ndarray]:
    """Slice a global batch for one host (multi-host input pipeline)."""
    def cut(x):
        per = x.shape[0] // n_hosts
        return x[host * per:(host + 1) * per]
    return {k: cut(v) for k, v in batch.items()}


def calibration_batch(vocab: int, seed: int = 0, n_seq: int = 128,
                      seq_len: int = 2048) -> np.ndarray:
    """The SparseGPT/Wanda calibration protocol: 128 × 2048 tokens."""
    return _gen_tokens(vocab, seed, n_seq, seq_len - 1, salt=0xCA1B)[:, :seq_len]
