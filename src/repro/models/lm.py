"""Model zoo assembly: one parameterized LM covering the six assigned
families (dense / moe / ssm / hybrid / audio / vlm).

Layer stacks are `lax.scan`'d over stacked parameters (leading dim L) so
compile time and HLO size stay O(1) in depth — at nemotron-340B scale
(96 layers) this is mandatory. The scan body is wrapped in
``jax.checkpoint`` with a configurable remat policy by the runtime step
builders (not here) so inference paths stay remat-free.

Hybrid (zamba2) layout: every layer is a Mamba-2 block; layers with
``idx % attn_every == attn_every - 1`` additionally run one *shared*
transformer block (attention + MLP) whose parameters are common to all
invocations — Zamba2's weight-sharing design. The shared block params
live outside the scanned stack.

Family quirks:
  audio — encoder-only (non-causal), input is precomputed frame
          embeddings (stub frontend per the assignment), no decode path.
  vlm   — M-RoPE positions (B, S, 3); prefill consumes precomputed patch
          embeddings, decode consumes text token ids.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packed_model import (has_hetero, layer_slice_range,
                                     segment_runs)
from repro.models import attention as attn_lib
from repro.models import mamba2 as mamba_lib
from repro.models import mlp as mlp_lib
from repro.models import moe as moe_lib
from repro.models.common import (ArchConfig, embed_init, dense_init,
                                 is_axes_leaf, positions_for, rms_norm,
                                 softmax_xent, tap_scope)

Array = jax.Array
AUX_LOSS_WEIGHT = 0.01


# ------------------------------------------------------------------
# Init
# ------------------------------------------------------------------

def _init_layer(cfg: ArchConfig, key: Array):
    """One layer of the stack (params, axes) — family dependent."""
    ks = jax.random.split(key, 4)
    if cfg.family in ("ssm", "hybrid"):
        mp, ma = mamba_lib.init_mamba(cfg, ks[0])
        return ({"norm": jnp.ones((cfg.d_model,), jnp.float32), "mamba": mp},
                {"norm": ("embed",), "mamba": ma})
    p: dict = {"attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
               "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    a: dict = {"attn_norm": ("embed",), "mlp_norm": ("embed",)}
    p["attn"], a["attn"] = attn_lib.init_attention(cfg, ks[1])
    if cfg.family == "moe":
        p["moe"], a["moe"] = moe_lib.init_moe(cfg, ks[2])
    else:
        p["mlp"], a["mlp"] = mlp_lib.init_mlp(cfg, ks[2])
    return p, a


def init(cfg: ArchConfig, key: Array):
    """Returns (params, axes). Stacked layers carry a leading "layers" dim."""
    kl, ke, kh, ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k)[0])(layer_keys)

    params: dict = {"layers": layers,
                    "final_norm": jnp.ones((cfg.d_model,), jnp.float32)}
    if cfg.input_mode == "tokens" or cfg.family == "vlm":
        params["embed"] = embed_init(ke, (cfg.vocab, cfg.d_model), cfg.dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(kh, (cfg.d_model, cfg.vocab),
                                       cfg.d_model, cfg.dtype)
    if cfg.family == "hybrid":
        sp: dict = {"attn_norm": jnp.ones((cfg.d_model,), jnp.float32),
                    "mlp_norm": jnp.ones((cfg.d_model,), jnp.float32)}
        k1, k2 = jax.random.split(ks)
        sp["attn"], _ = attn_lib.init_attention(cfg, k1)
        sp["mlp"], _ = mlp_lib.init_mlp(cfg, k2)
        params["shared_attn"] = sp
    return params, param_axes(cfg)


def abstract_params(cfg: ArchConfig):
    """(ShapeDtypeStruct pytree, axes) without allocating — dry-run path."""
    shapes = jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0))[0])
    return shapes, param_axes(cfg)


def _layer_axes(cfg: ArchConfig) -> dict:
    """Static logical axes of one layer — no array allocation."""
    if cfg.family in ("ssm", "hybrid"):
        return {"norm": ("embed",), "mamba": mamba_lib.mamba_axes()}
    a: dict = {"attn_norm": ("embed",), "mlp_norm": ("embed",),
               "attn": attn_lib.attention_axes()}
    if cfg.family == "moe":
        a["moe"] = moe_lib.moe_axes(cfg)
    else:
        a["mlp"] = mlp_lib.mlp_axes(cfg)
    return a


def param_axes(cfg: ArchConfig):
    """Static logical-axes pytree (no array work)."""
    layer_axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                              _layer_axes(cfg),
                              is_leaf=is_axes_leaf)
    axes: dict = {"layers": layer_axes, "final_norm": ("embed",)}
    if cfg.input_mode == "tokens" or cfg.family == "vlm":
        axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings:
        axes["lm_head"] = ("embed", "vocab")
    if cfg.family == "hybrid":
        axes["shared_attn"] = {
            "attn_norm": ("embed",), "mlp_norm": ("embed",),
            "attn": attn_lib.attention_axes(), "mlp": mlp_lib.mlp_axes(cfg)}
    return axes


def param_count(cfg: ArchConfig) -> int:
    import math
    shapes = jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0))[0])
    return sum(math.prod(l.shape) if l.shape else 1
               for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ArchConfig) -> int:
    """MoE: params touched per token (top_k of n_experts) — for the
    6·N_active·D model-FLOPs roofline term."""
    total = param_count(cfg)
    if cfg.family != "moe":
        return total
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = cfg.n_layers * per_expert * (cfg.n_experts - cfg.top_k)
    return total - inactive


# ------------------------------------------------------------------
# Forward (train / prefill)
# ------------------------------------------------------------------

def _shared_block(cfg: ArchConfig, sp: dict, h: Array, positions: Array
                  ) -> Array:
    with tap_scope("shared"):
        with tap_scope("attn"):
            a = attn_lib.multihead_attention(
                cfg, sp["attn"], rms_norm(h, sp["attn_norm"], cfg.norm_eps),
                positions)
        h = h + a
        with tap_scope("mlp"):
            m = mlp_lib.mlp(cfg, sp["mlp"],
                            rms_norm(h, sp["mlp_norm"], cfg.norm_eps))
    return h + m


def _layer_fwd(cfg: ArchConfig, params: dict, lp: dict, idx: Array,
               h: Array, positions: Array) -> Tuple[Array, Array]:
    """Returns (h, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid" and cfg.attn_every:
            apply_attn = (idx % cfg.attn_every) == (cfg.attn_every - 1)
            if isinstance(apply_attn, jax.core.Tracer):
                h = jax.lax.cond(
                    apply_attn,
                    lambda hh: _shared_block(cfg, params["shared_attn"], hh,
                                             positions),
                    lambda hh: hh, h)
            elif bool(apply_attn):
                # concrete layer index (eager calibration path): run the
                # shared block un-traced so activation taps see values
                h = _shared_block(cfg, params["shared_attn"], h, positions)
        with tap_scope("mamba"):
            h = h + mamba_lib.mamba_block(
                cfg, lp["mamba"], rms_norm(h, lp["norm"], cfg.norm_eps))
        return h, aux
    with tap_scope("attn"):
        a = attn_lib.multihead_attention(
            cfg, lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps),
            positions)
    h = h + a
    hin = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        with tap_scope("moe"):
            y, aux = moe_lib.moe_ffn(cfg, lp["moe"], hin)
    else:
        with tap_scope("mlp"):
            y = mlp_lib.mlp(cfg, lp["mlp"], hin)
    return h + y, aux


def embed_inputs(cfg: ArchConfig, params: dict, inputs: Array) -> Array:
    """Token ids (int) -> table lookup; float inputs pass through (stub
    modality frontends provide embeddings directly)."""
    if jnp.issubdtype(inputs.dtype, jnp.integer):
        return params["embed"][inputs]
    return inputs.astype(cfg.dtype)


def unembed(cfg: ArchConfig, params: dict, h: Array) -> Array:
    if cfg.tie_embeddings:
        return h @ params["embed"].T
    return h @ params["lm_head"]


def forward(cfg: ArchConfig, params: dict, inputs: Array,
            positions: Optional[Array] = None,
            remat_policy: Optional[Any] = None,
            remat_block: int = 1,
            segments: Optional[Tuple[Tuple[int, int], ...]] = None
            ) -> Tuple[Array, Array]:
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss).

    ``remat_block`` > 1 enables sqrt-L block checkpointing: layers are
    scanned in groups of ``remat_block``; only group-boundary carries are
    saved for the backward pass (G + K live carries instead of L — the
    change that fits nemotron-340B's activations into v5e HBM).

    ``segments`` overrides the layer-axis partition of the segmented
    path (benchmarking the unrolled equivalent = per-layer segments);
    heterogeneous packed stacks compute it via ``segment_runs``."""
    from repro.runtime.meshctx import DP, hint
    b, s = inputs.shape[0], inputs.shape[1]
    if positions is None:
        positions = positions_for(cfg, b, s)
    h = embed_inputs(cfg, params, inputs)
    h = hint(h, DP, None, None)

    stacked = params["layers"]

    def body(carry, xs):
        h, aux = carry
        lp, idx = xs
        h = hint(h, DP, None, None)   # re-pin batch sharding per layer
        h, a = _layer_fwd(cfg, params, lp, idx, h, positions)
        return (h, aux + a), None

    if has_hetero(stacked) or segments is not None:
        # Heterogeneous packed stacks (PackedStack leaves) change leaf
        # shapes across layers, so ONE lax.scan can't span the model —
        # but the layer axis partitions into maximal contiguous runs
        # with identical packed signatures, and each run scans: one
        # traced layer body per segment (O(#segments) compile, not
        # O(L)). Serving-only path (packed weights never train), so
        # remat is irrelevant.
        if segments is None:
            segments = segment_runs(stacked, cfg.n_layers)
        carry = (h, jnp.zeros((), jnp.float32))
        for lo, hi in segments:
            carry, _ = _seg_scan(
                body, carry,
                (layer_slice_range(stacked, lo, hi), jnp.arange(lo, hi)),
                hi - lo)
        h, aux = carry
        h = rms_norm(h, params["final_norm"], cfg.norm_eps)
        return unembed(cfg, params, h), aux

    init = (h, jnp.zeros((), jnp.float32))
    k = remat_block
    if k > 1 and cfg.n_layers % k == 0:
        g = cfg.n_layers // k

        def block(carry, xs_blk):
            return jax.lax.scan(body, carry, xs_blk)

        if remat_policy is not None:
            block = jax.checkpoint(block, policy=remat_policy)
        stacked_g = jax.tree.map(
            lambda x: x.reshape(g, k, *x.shape[1:]), stacked)
        idx_g = jnp.arange(cfg.n_layers).reshape(g, k)
        (h, aux), _ = jax.lax.scan(block, init, (stacked_g, idx_g))
    else:
        if remat_policy is not None:
            body = jax.checkpoint(body, policy=remat_policy)
        (h, aux), _ = jax.lax.scan(
            body, init, (stacked, jnp.arange(cfg.n_layers)))
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, h), aux


def loss_fn(cfg: ArchConfig, params: dict, batch: dict,
            remat_policy: Optional[Any] = None,
            remat_block: int = 1) -> Tuple[Array, dict]:
    logits, aux = forward(cfg, params, batch["inputs"],
                          batch.get("positions"), remat_policy,
                          remat_block)
    ce = softmax_xent(logits, batch["labels"], batch.get("mask"))
    loss = ce + AUX_LOSS_WEIGHT * aux
    return loss, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------
# Serving: prefill + decode
# ------------------------------------------------------------------

class LayerCache(NamedTuple):
    """Union cache — exactly one member populated per family."""
    kv: Any
    mamba: Any
    shared_kv: Any   # hybrid: KV caches of shared-attn invocations


def n_shared_invocations(cfg: ArchConfig) -> int:
    if cfg.family != "hybrid" or not cfg.attn_every:
        return 0
    return cfg.n_layers // cfg.attn_every


def init_cache(cfg: ArchConfig, batch: int, s_max: int,
               length: int = 0) -> LayerCache:
    if cfg.family in ("ssm", "hybrid"):
        mc = jax.vmap(lambda _: mamba_lib.init_mamba_cache(cfg, batch))(
            jnp.arange(cfg.n_layers))
        skv = None
        if cfg.family == "hybrid":
            ninv = n_shared_invocations(cfg)
            skv = jax.vmap(
                lambda _: attn_lib.init_kv_cache(cfg, batch, s_max, length))(
                jnp.arange(ninv))
        return LayerCache(None, mc, skv)
    kv = jax.vmap(lambda _: attn_lib.init_kv_cache(cfg, batch, s_max, length))(
        jnp.arange(cfg.n_layers))
    return LayerCache(kv, None, None)


def cache_axes(cfg: ArchConfig) -> LayerCache:
    if cfg.family in ("ssm", "hybrid"):
        ma = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                          mamba_lib.mamba_cache_axes(),
                          is_leaf=is_axes_leaf)
        sa = None
        if cfg.family == "hybrid":
            sa = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                              attn_lib.kv_cache_axes(cfg),
                              is_leaf=is_axes_leaf)
        return LayerCache(None, ma, sa)
    ka = jax.tree.map(lambda ax: ("layers",) + tuple(ax),
                      attn_lib.kv_cache_axes(cfg),
                      is_leaf=is_axes_leaf)
    return LayerCache(ka, None, None)


def _layer_decode(cfg: ArchConfig, params: dict, lp: dict, idx: Array,
                  h: Array, kv_l, positions: Array):
    if cfg.family in ("ssm", "hybrid"):
        with tap_scope("mamba"):
            y, mc = mamba_lib.mamba_decode_step(
                cfg, lp["mamba"], rms_norm(h, lp["norm"], cfg.norm_eps), kv_l)
        return h + y, mc
    with tap_scope("attn"):
        a, kc = attn_lib.decode_attention(
            cfg, lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps),
            kv_l, positions)
    h = h + a
    hin = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        with tap_scope("moe"):
            y, _ = moe_lib.moe_ffn(cfg, lp["moe"], hin)
    else:
        with tap_scope("mlp"):
            y = mlp_lib.mlp(cfg, lp["mlp"], hin)
    return h + y, kc


def _shared_block_decode(cfg: ArchConfig, sp: dict, h: Array,
                         kv: attn_lib.KVCache, positions: Array):
    with tap_scope("shared"):
        with tap_scope("attn"):
            a, kv = attn_lib.decode_attention(
                cfg, sp["attn"], rms_norm(h, sp["attn_norm"], cfg.norm_eps),
                kv, positions)
        h = h + a
        with tap_scope("mlp"):
            m = mlp_lib.mlp(cfg, sp["mlp"],
                            rms_norm(h, sp["mlp_norm"], cfg.norm_eps))
    return h + m, kv


def _cat_parts(parts):
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)


def _seg_scan(body, carry, xs, length: int):
    """Drive one layer segment: a real ``lax.scan`` for multi-layer
    runs, a direct body call for length-1 runs — no scan machinery and
    no layer-axis ``dynamic_slice`` for trivial depth (this is where
    the old ~15% segmented-vs-unrolled overhead lived; per-layer
    segmentation now IS the unrolled path). The direct call also passes
    a concrete layer index, so trace counts stay one body per segment
    either way."""
    if length > 1:
        return jax.lax.scan(body, carry, xs)
    xs0 = jax.tree.map(lambda a: a[0], xs)
    carry, y = body(carry, xs0)
    return carry, jax.tree.map(lambda a: a[None], y)


def _slice_layers(tree, lo: int, hi: int, n_layers: int):
    """Layer-axis slice of stacked per-layer state; the full range is
    the identity (the homogeneous one-segment path copies nothing)."""
    if lo == 0 and hi == n_layers:
        return tree
    return jax.tree.map(lambda x: x[lo:hi], tree)


def decode_step(cfg: ArchConfig, params: dict, cache: LayerCache,
                token: Array, positions: Array,
                segments: Optional[Tuple[Tuple[int, int], ...]] = None
                ) -> Tuple[Array, LayerCache]:
    """One decode step. token (B, 1) int32 (or (B,1,D) embeds);
    positions (B,1[,3]). Returns (logits (B,1,V), new cache).

    The layer loop is one ``lax.scan`` per contiguous same-signature
    segment (``segment_runs``): a homogeneous stack is the single
    segment (0, L) — the classic one-scan decode — while heterogeneous
    packed stacks trace O(#segments) layer bodies instead of O(L).
    Per-segment caches are sliced from / concatenated back into the
    same stacked buffers, so segmentations are interchangeable step to
    step; ``segments`` overrides the partition (per-layer segments =
    the old unrolled path, kept reachable for benchmarks/tests)."""
    from repro.runtime.meshctx import DP, hint
    h = embed_inputs(cfg, params, token)
    h = hint(h, DP, None, None)

    stacked = params["layers"]
    if segments is None:
        segments = segment_runs(stacked, cfg.n_layers)

    if cfg.family in ("ssm", "hybrid"):
        if cfg.family == "hybrid":
            per = cfg.attn_every

            def body(carry, xs):
                h, skv = carry                  # skv: stacked (ninv, …) caches
                lp, mc_l, idx = xs
                h = hint(h, DP, None, None)

                def with_attn(args):
                    h, skv = args
                    inv = idx // per
                    skv_l = jax.tree.map(lambda x: x[inv], skv)
                    h2, skv_new = _shared_block_decode(
                        cfg, params["shared_attn"], h,
                        attn_lib.KVCache(*skv_l), positions)
                    skv2 = jax.tree.map(
                        lambda buf, new: jax.lax.dynamic_update_index_in_dim(
                            buf, new, inv, 0), skv, skv_new)
                    return h2, skv2

                h, skv = jax.lax.cond((idx % per) == (per - 1),
                                      with_attn, lambda a: a, (h, skv))
                h, mc_new = _layer_decode(cfg, params, lp, idx, h, mc_l,
                                          positions)
                return (h, skv), mc_new

            carry, mc_parts = (h, cache.shared_kv), []
            for lo, hi in segments:
                carry, mc_new = _seg_scan(
                    body, carry,
                    (layer_slice_range(stacked, lo, hi),
                     _slice_layers(cache.mamba, lo, hi, cfg.n_layers),
                     jnp.arange(lo, hi)), hi - lo)
                mc_parts.append(mc_new)
            (h, skv) = carry
            new_cache = LayerCache(None, _cat_parts(mc_parts), skv)
        else:
            def body(h, xs):
                lp, mc_l, idx = xs
                h = hint(h, DP, None, None)
                h, mc_new = _layer_decode(cfg, params, lp, idx, h,
                                          mc_l, positions)
                return h, mc_new

            mc_parts = []
            for lo, hi in segments:
                h, mc_new = _seg_scan(
                    body, h,
                    (layer_slice_range(stacked, lo, hi),
                     _slice_layers(cache.mamba, lo, hi, cfg.n_layers),
                     jnp.arange(lo, hi)), hi - lo)
                mc_parts.append(mc_new)
            new_cache = LayerCache(None, _cat_parts(mc_parts), None)
    else:
        def body(h, xs):
            lp, kv_l, idx = xs
            h = hint(h, DP, None, None)   # re-pin batch sharding per layer
            h, kv_new = _layer_decode(cfg, params, lp, idx, h,
                                      attn_lib.KVCache(*kv_l), positions)
            return h, kv_new

        kv_parts = []
        for lo, hi in segments:
            h, kv_new = _seg_scan(
                body, h,
                (layer_slice_range(stacked, lo, hi),
                 _slice_layers(cache.kv, lo, hi, cfg.n_layers),
                 jnp.arange(lo, hi)), hi - lo)
            kv_parts.append(kv_new)
        new_cache = LayerCache(_cat_parts(kv_parts), None, None)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, h), new_cache


def _layer_decode_paged(cfg: ArchConfig, params: dict, lp: dict, idx: Array,
                        h: Array, pool_l, block_tables: Array,
                        lengths: Array, positions: Array, active: Array):
    with tap_scope("attn"):
        a, pool_l = attn_lib.paged_decode_attention(
            cfg, lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps),
            pool_l, block_tables, lengths, positions, active)
    h = h + a
    hin = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if cfg.family == "moe":
        with tap_scope("moe"):
            y, _ = moe_lib.moe_ffn(cfg, lp["moe"], hin)
    else:
        with tap_scope("mlp"):
            y = mlp_lib.mlp(cfg, lp["mlp"], hin)
    return h + y, pool_l


def paged_decode_step(cfg: ArchConfig, params: dict, paged,
                      block_tables: Array, lengths: Array, token: Array,
                      active: Array,
                      segments: Optional[Tuple[Tuple[int, int], ...]] = None
                      ):
    """One decode step against the paged KV cache (serving engine path).

    token (R, 1) int32 over the engine's fixed request slots; paged a
    ``serving.paged_cache.PagedKVCache``; block_tables (R, n_bt) int32;
    lengths (R,) tokens already cached per row; active (R,) bool.
    Returns (logits (R, 1, V), new paged cache). Inactive rows write
    nothing into the pool and their logits are garbage-but-finite.

    The layer loop reuses the segmented-scan machinery of
    ``decode_step`` — heterogeneous packed stacks trace O(#segments)
    bodies — with the per-layer pool slices riding the scan xs exactly
    like the dense KV cache does. KV-attention families only (the
    engine gates SSM/hybrid out at construction)."""
    from repro.runtime.meshctx import DP, hint
    if cfg.family in ("ssm", "hybrid", "audio"):
        raise ValueError(f"paged decode: unsupported family {cfg.family!r}")
    r = token.shape[0]
    positions = positions_for(cfg, r, 1, offset=lengths[:, None])
    h = embed_inputs(cfg, params, token)
    h = hint(h, DP, None, None)

    stacked = params["layers"]
    if segments is None:
        segments = segment_runs(stacked, cfg.n_layers)

    def body(h, xs):
        lp, pool_l, idx = xs
        h = hint(h, DP, None, None)
        h, pool_new = _layer_decode_paged(cfg, params, lp, idx, h, pool_l,
                                          block_tables, lengths, positions,
                                          active)
        return h, pool_new

    pool_parts = []
    for lo, hi in segments:
        h, pool_new = _seg_scan(
            body, h,
            (layer_slice_range(stacked, lo, hi),
             _slice_layers(paged, lo, hi, cfg.n_layers),
             jnp.arange(lo, hi)), hi - lo)
        pool_parts.append(pool_new)
    new_paged = _cat_parts(pool_parts)

    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, h), new_paged


def prefill(cfg: ArchConfig, params: dict, inputs: Array,
            positions: Optional[Array] = None) -> Tuple[Array, Array]:
    """Prefill = full forward returning logits (cache fill is modeled as
    the forward pass; the dry-run prefill cell lowers this fn). Encoder
    (audio) prefill is just the forward."""
    logits, _ = forward(cfg, params, inputs, positions)
    return logits
