"""Feed-forward blocks: gated (SwiGLU) and plain (GELU / squared-ReLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packed_model import linear
from repro.models.common import ArchConfig, activation, dense_init

Array = jax.Array


def is_gated(act: str) -> bool:
    return act == "swiglu"


def mlp_axes(cfg: ArchConfig) -> dict:
    if is_gated(cfg.act):
        return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
                "w_down": ("ffn", "embed")}
    return {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}


def init_mlp(cfg: ArchConfig, key: Array, d_ff: int | None = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if is_gated(cfg.act):
        p = {
            "w_gate": dense_init(ks[0], (d, f), d, cfg.dtype),
            "w_up": dense_init(ks[1], (d, f), d, cfg.dtype),
            "w_down": dense_init(ks[2], (f, d), f, cfg.dtype),
        }
    else:
        p = {
            "w_up": dense_init(ks[0], (d, f), d, cfg.dtype),
            "w_down": dense_init(ks[1], (f, d), f, cfg.dtype),
        }
    return p, mlp_axes(cfg)


def mlp(cfg: ArchConfig, p: dict, x: Array) -> Array:
    if is_gated(cfg.act):
        h = jax.nn.silu(linear(x, p["w_gate"], tap="w_gate")) * \
            linear(x, p["w_up"], tap="w_up")
    else:
        kind = "gelu" if cfg.act == "gelu" else "relu2"
        h = activation(linear(x, p["w_up"], tap="w_up"), kind)
    return linear(h, p["w_down"], tap="w_down")
