"""GQA attention: query-chunked (flash-style) prefill/train path and a
single-token decode path against a preallocated KV cache.

Memory discipline: the (S, S) score matrix is never materialized — the
train/prefill path lax.scan's over query chunks of ``cfg.q_chunk`` rows,
so live attention memory is O(q_chunk * S) per (batch, head) instead of
O(S^2). This is the XLA-level equivalent of flash attention's tiling.

Sharding discipline (the 96-head nemotron lesson): the full-sequence
path expands K/V to the full head count (`jnp.repeat` over the group
dim) and keeps every tensor in plain (B, S, H, dh) layout so the TP
sharding of H propagates through reshapes cleanly; `meshctx.hint` pins
the expanded K/V and the per-chunk scores to the "model" axis. The
decode path keeps K/V grouped (cache stays at n_kv heads — 12x smaller
for 96/8 GQA) and shards the cache over sequence ("kv_seq" -> model):
each model shard scores its sequence slice and GSPMD turns the softmax
normalization into the flash-decode all-reduce.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packed_model import linear
from repro.models.common import ArchConfig, dense_init, rotate
from repro.runtime.meshctx import hint

Array = jax.Array

NEG_INF = -1e30


def attention_axes() -> dict:
    return {
        "wq": ("embed", "heads"),
        "wk": ("embed", "kv"),
        "wv": ("embed", "kv"),
        "wo": ("heads", "embed"),
    }


def init_attention(cfg: ArchConfig, key: Array):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {
        "wq": dense_init(ks[0], (d, cfg.d_q), d, cfg.dtype),
        "wk": dense_init(ks[1], (d, cfg.d_kv), d, cfg.dtype),
        "wv": dense_init(ks[2], (d, cfg.d_kv), d, cfg.dtype),
        "wo": dense_init(ks[3], (cfg.d_q, d), cfg.d_q, cfg.dtype),
    }
    return p, attention_axes()


def multihead_attention(
    cfg: ArchConfig,
    p: dict,
    x: Array,
    positions: Array,
) -> Array:
    """Full-sequence attention (train / prefill), query-chunked.
    x (B, S, D) -> (B, S, D). Causality from cfg.causal."""
    from repro.runtime.meshctx import current_mesh
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    g = h // kv
    q = linear(x, p["wq"], tap="wq").reshape(b, s, h, dh)
    k = linear(x, p["wk"], tap="wk").reshape(b, s, kv, dh)
    v = linear(x, p["wv"], tap="wv").reshape(b, s, kv, dh)
    q = rotate(cfg, q, positions)
    k = rotate(cfg, k, positions)
    if g > 1:                       # expand KV to full heads: clean TP on H
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)

    # TP strategy: shard heads over "model" when they divide; otherwise
    # fall back to sequence parallelism — shard the *query chunk* over
    # "model" so the S^2 score work splits even with indivisible head
    # counts (llama3.2's 24H / qwen2-vl's 12H on a 16-way axis). K/V stay
    # replicated either way (they already are when heads can't shard).
    mesh = current_mesh()
    sp_mode = bool(mesh is not None and "model" in mesh.axis_names
                   and h % mesh.shape["model"] != 0)

    q = q * (dh ** -0.5)
    if not sp_mode:
        q = hint(q, None, None, "model", None)
        k = hint(k, None, None, "model", None)
        v = hint(v, None, None, "model", None)

    cq = min(cfg.q_chunk, s)
    n_chunks = max(s // cq, 1)
    if s % cq:
        cq, n_chunks = s, 1
    kv_pos = jnp.arange(s, dtype=jnp.int32)

    def chunk(carry, inp):
        qc, qpos = inp                                    # (B,cq,H,dh), (cq,)
        if sp_mode:
            qc = hint(qc, None, "model", None, None)
        logits = jnp.einsum("bqhd,bshd->bhqs", qc, k,
                            preferred_element_type=jnp.float32)
        if sp_mode:
            logits = hint(logits, None, None, "model", None)
        else:
            logits = hint(logits, None, "model", None, None)
        if cfg.causal:
            mask = qpos[:, None] >= kv_pos[None, :]
            logits = jnp.where(mask[None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(cfg.dtype)
        out = jnp.einsum("bhqs,bshd->bqhd", probs, v)
        if sp_mode:
            out = hint(out, None, "model", None, None)
        return carry, out

    q_cs = q.reshape(b, n_chunks, cq, h, dh).swapaxes(0, 1)
    qpos_rows = positions[..., 0] if positions.ndim == 3 else positions
    qpos_cs = qpos_rows[0].reshape(n_chunks, cq)
    _, out = jax.lax.scan(chunk, None, (q_cs, qpos_cs))
    out = out.swapaxes(0, 1).reshape(b, s, cfg.d_q)
    return linear(out, p["wo"], tap="wo")


# ------------------------------------------------------------------
# Decode path
# ------------------------------------------------------------------

class KVCache(NamedTuple):
    k: Array        # (B, S_max, Kv, dh) — cfg.dtype, or int8 when quantized
    v: Array        # (B, S_max, Kv, dh)
    length: Array   # scalar int32 — tokens currently valid
    k_scale: Optional[Array] = None   # (B, S_max, Kv) f32, int8 mode only
    v_scale: Optional[Array] = None


def init_kv_cache(cfg: ArchConfig, batch: int, s_max: int,
                  length: int = 0) -> KVCache:
    shp = (batch, s_max, cfg.n_kv, cfg.d_head)
    if cfg.kv_quant:
        sshp = (batch, s_max, cfg.n_kv)
        return KVCache(jnp.zeros(shp, jnp.int8), jnp.zeros(shp, jnp.int8),
                       jnp.asarray(length, jnp.int32),
                       jnp.zeros(sshp, jnp.float32),
                       jnp.zeros(sshp, jnp.float32))
    return KVCache(jnp.zeros(shp, cfg.dtype), jnp.zeros(shp, cfg.dtype),
                   jnp.asarray(length, jnp.int32))


def kv_cache_axes(cfg: ArchConfig) -> KVCache:
    """Batch over data, cached sequence over model (SP/flash-decode
    sharding: each model shard owns a KV slice; softmax normalization
    crosses shards as an all-reduce)."""
    scale_ax = ("batch", "kv_seq", None) if cfg.kv_quant else None
    return KVCache(("batch", "kv_seq", None, None),
                   ("batch", "kv_seq", None, None), (),
                   scale_ax, scale_ax)


def _quantize_token(t: Array):
    """(B, 1, Kv, dh) -> int8 payload + (B, 1, Kv) scale."""
    t32 = t.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(t32), axis=-1) / 127.0, 1e-8)
    q = jnp.clip(jnp.round(t32 / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale


def decode_attention(
    cfg: ArchConfig, p: dict, x: Array, cache: KVCache, positions: Array,
) -> Tuple[Array, KVCache]:
    """One-token step. x (B, 1, D); positions (B, 1[, 3]).

    int8 mode: the cache is stored and *read* as int8; per-(token, head)
    scales are folded into the score/probability tensors, so no
    dequantized copy of the cache is ever materialized (on TPU the
    convert fuses into the dot's operand pipeline)."""
    b, s, d = x.shape
    kv, g, dh = cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.d_head
    q = linear(x, p["wq"], tap="wq").reshape(b, s, cfg.n_heads, dh)
    k_new = linear(x, p["wk"], tap="wk").reshape(b, s, kv, dh)
    v_new = linear(x, p["wv"], tap="wv").reshape(b, s, kv, dh)
    q = rotate(cfg, q, positions)
    k_new = rotate(cfg, k_new, positions)

    idx = cache.length
    if cfg.kv_quant:
        k_q, k_s = _quantize_token(k_new)
        v_q, v_s = _quantize_token(v_new)
        k = jax.lax.dynamic_update_slice(cache.k, k_q, (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(cache.v, v_q, (0, idx, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(cache.k_scale, k_s,
                                               (0, idx, 0))
        v_scale = jax.lax.dynamic_update_slice(cache.v_scale, v_s,
                                               (0, idx, 0))
        new_cache = KVCache(k, v, idx + s, k_scale, v_scale)
    else:
        k = jax.lax.dynamic_update_slice(
            cache.k, k_new.astype(cache.k.dtype), (0, idx, 0, 0))
        v = jax.lax.dynamic_update_slice(
            cache.v, v_new.astype(cache.v.dtype), (0, idx, 0, 0))
        new_cache = KVCache(k, v, idx + s, cache.k_scale, cache.v_scale)
        k_scale = v_scale = None

    # grouped form: cache stays at kv heads; q (B, 1, Kv, G, dh)
    q = q.reshape(b, s, kv, g, dh) * (dh ** -0.5)
    kk = k.astype(cfg.dtype) if cfg.kv_quant else k
    logits = jnp.einsum("bqkgd,bskd->bkgqs", q, kk,
                        preferred_element_type=jnp.float32)
    if cfg.kv_quant:
        logits = logits * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    logits = hint(logits, None, None, None, None, "model")  # S over model
    valid = jnp.arange(k.shape[1], dtype=jnp.int32) <= idx
    logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    if cfg.kv_quant:
        probs = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    probs = probs.astype(cfg.dtype)
    vv = v.astype(cfg.dtype) if cfg.kv_quant else v
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vv)
    out = out.reshape(b, s, cfg.d_q)
    return linear(out, p["wo"], tap="wo"), new_cache


def paged_decode_attention(cfg: ArchConfig, p: dict, x: Array, pool,
                           block_tables: Array, lengths: Array,
                           positions: Array, active: Array):
    """One-token decode against a paged KV cache (one layer's pool).

    x (R, 1, D); pool a single-layer ``serving.paged_cache.PagedKVCache``
    slice (k/v (n_blocks, bs, KV, dh)); block_tables (R, n_bt) int32;
    lengths (R,) tokens already cached per row (also the write
    position); active (R,) bool — inactive rows write nothing and
    return zeros. Returns (out (R, 1, D), updated pool).

    The new token's K/V scatter into block ``block_tables[r, len//bs]``
    at offset ``len % bs``; attention then reads the whole stream
    through the block table via the ``flash_decode_paged`` kernel
    (scalar-prefetched indices), int8 path included."""
    from repro.kernels import ops
    from repro.serving.paged_cache import paged_write
    b, s, d = x.shape
    kv, g, dh = cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.d_head
    q = linear(x, p["wq"], tap="wq").reshape(b, s, cfg.n_heads, dh)
    k_new = linear(x, p["wk"], tap="wk").reshape(b, s, kv, dh)
    v_new = linear(x, p["wv"], tap="wv").reshape(b, s, kv, dh)
    q = rotate(cfg, q, positions)
    k_new = rotate(cfg, k_new, positions)

    bs_blk = pool.block_size
    n_bt = block_tables.shape[1]
    # physical write slot; clamp shields idle rows with stale lengths
    # (their write is dropped by `active` anyway)
    blk = jnp.take_along_axis(
        block_tables, jnp.clip(lengths // bs_blk, 0, n_bt - 1)[:, None],
        axis=1)[:, 0]
    off = lengths % bs_blk
    if cfg.kv_quant:
        k_q, k_s = _quantize_token(k_new)
        v_q, v_s = _quantize_token(v_new)
        pool = pool._replace(
            k=paged_write(pool.k, k_q[:, 0], blk, off, active),
            v=paged_write(pool.v, v_q[:, 0], blk, off, active),
            k_scale=paged_write(pool.k_scale, k_s[:, 0], blk, off, active),
            v_scale=paged_write(pool.v_scale, v_s[:, 0], blk, off, active))
    else:
        pool = pool._replace(
            k=paged_write(pool.k, k_new[:, 0], blk, off, active),
            v=paged_write(pool.v, v_new[:, 0], blk, off, active))

    qg = q[:, 0].reshape(b, kv, g, dh) * (dh ** -0.5)
    att_len = jnp.where(active, lengths + 1, 0).astype(jnp.int32)
    out = ops.flash_decode_paged_attention(
        qg, pool.k, pool.v, block_tables, att_len,
        pool.k_scale, pool.v_scale)
    out = out.reshape(b, 1, cfg.d_q).astype(x.dtype)
    return linear(out, p["wo"], tap="wo"), pool
