"""Shared model building blocks: norms, activations, rotary embeddings,
initializers, and the logical-axis annotation convention.

Every ``init_*`` helper returns ``(params, axes)`` where ``axes`` is a
pytree of the same structure whose leaves are tuples of *logical axis
names* (one per tensor dim). The sharding planner (``repro.runtime.
sharding``) maps logical names -> mesh axes with divisibility checks.

Logical axis vocabulary:
  "layers"   stacked-layer leading dim (scan axis, never sharded)
  "vocab"    vocabulary dim            -> "model"
  "embed"    d_model dim               -> fsdp axes ("data" [, "pod"])
  "heads"    flattened q-head dim      -> "model" (if divisible)
  "kv"       flattened kv-head dim     -> "model" (if divisible)
  "ffn"      feed-forward hidden dim   -> "model"
  "experts"  MoE expert dim            -> "model"
  "ssm"      mamba inner dim           -> "model"
  null (None) unsharded dim
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ------------------------------------------------------------------
# Activation taps
# ------------------------------------------------------------------
#
# The calibration statistics that drive compression (‖X‖₂ column norms,
# X^T X Hessians) are captured from the *real* model forward instead of
# re-deriving layer wiring elsewhere. The mechanism:
#
#   * ``core.packed_model.linear(x, w, tap="wq")`` — the single matmul
#     dispatch chokepoint — reports its input ``x`` here when a capture
#     is active;
#   * modules that own several linears run them under ``tap_scope``
#     prefixes ("attn", "mlp", "moe", "shared", "mamba"), so full tap
#     names ("attn.wq", "moe.shared.w_gate", "mamba.out") match the
#     compression pipeline's ``linear_paths`` exactly;
#   * ``with tap_capture(hessian=...) as tap:`` activates recording for
#     the enclosed (eager) forward and accumulates streaming fp32
#     reductions per tap name.
#
# Captures are thread-local and nestable; recording is a no-op (one
# list check) when no capture is active, so instrumented forwards cost
# nothing in production, and the scope/record calls inside scanned layer
# bodies only ever execute at trace time.

_tap_state = threading.local()


def _tap_captures() -> List["TapCapture"]:
    if not hasattr(_tap_state, "captures"):
        _tap_state.captures = []
    return _tap_state.captures


def _tap_prefix() -> List[str]:
    if not hasattr(_tap_state, "prefix"):
        _tap_state.prefix = []
    return _tap_state.prefix


class TapCapture:
    """Streaming per-linear activation statistics for one capture scope.

    Per tap name, accumulates (fp32) the column sum-of-squares of every
    recorded input — ``norms(name)`` is then ``diag(sqrt(X^T X))`` — and,
    with ``hessian=True``, the full Gram matrix ``X^T X``. Stacked
    (per-expert) records keep a leading expert dim: norms (E, D_in),
    Hessians (E, D_in, D_in), holding exactly the dispatched-token
    subset each expert served.
    """

    def __init__(self, hessian: bool = False,
                 hessian_names: Optional[set] = None):
        self.want_hessian = hessian
        # restrict the O(T·D²) Gram accumulation to these tap names
        # (None = all); norms are cheap and always recorded
        self._hess_names = (None if hessian_names is None
                            else set(hessian_names))
        self._sumsq: Dict[str, Array] = {}
        self._hess: Dict[str, Array] = {}
        self._count: Dict[str, Any] = {}   # int, or (E,) for stacked taps
        # taps fed by the same array in one forward (wq/wk/wv share hn,
        # moe w_gate/w_up share expert_in) share one Gram compute. The
        # cache is bounded: entries hold a strong ref to the recorded
        # activation (keeps the id valid), and same-input taps fire back
        # to back, so a few slots give full dedup without pinning every
        # batch's activations in a streaming multi-batch capture
        self._gram_cache: Dict[Tuple[int, str], Tuple[Array, Array]] = {}
        self._gram_cache_slots = 4

    # -- recording ---------------------------------------------------

    @staticmethod
    def _check_concrete(name: str, x):
        if isinstance(x, jax.core.Tracer):
            raise RuntimeError(
                f"activation tap {name!r} hit a traced value: run the "
                "calibration forward eagerly (outside jit/scan) under "
                "tap_capture")

    def _want_hess(self, name: str) -> bool:
        return self.want_hessian and (self._hess_names is None
                                      or name in self._hess_names)

    def _gram(self, x: Array, kind: str, compute) -> Array:
        key = (id(x), kind)
        hit = self._gram_cache.get(key)
        if hit is not None and hit[0] is x:
            return hit[1]
        g = compute()
        while len(self._gram_cache) >= self._gram_cache_slots:
            self._gram_cache.pop(next(iter(self._gram_cache)))  # FIFO
        self._gram_cache[key] = (x, g)
        return g

    def record(self, name: str, x: Array) -> None:
        """x (..., D_in): all leading dims are token dims."""
        self._check_concrete(name, x)
        f = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        ss = jnp.sum(f * f, axis=0)
        self._sumsq[name] = self._sumsq.get(name, 0.0) + ss
        self._count[name] = self._count.get(name, 0) + f.shape[0]
        if self._want_hess(name):
            g = self._gram(x, "flat", lambda: f.T @ f)
            self._hess[name] = self._hess.get(name, 0.0) + g

    def record_stacked(self, name: str, x: Array, stack_axis: int) -> None:
        """x with one stacked dim (experts) at ``stack_axis``; remaining
        leading dims are token dims, last dim is D_in."""
        self._check_concrete(name, x)
        xe = jnp.moveaxis(x, stack_axis, 0)
        e = xe.shape[0]
        f = xe.reshape(e, -1, xe.shape[-1]).astype(jnp.float32)
        ss = jnp.sum(f * f, axis=1)                      # (E, D)
        self._sumsq[name] = self._sumsq.get(name, 0.0) + ss
        # per-expert token counts: only rows actually dispatched (unused
        # capacity slots are zero rows and must not inflate the count)
        nz = jnp.sum(jnp.any(f != 0, axis=-1), axis=1)   # (E,)
        self._count[name] = self._count.get(name, 0) + nz
        if self._want_hess(name):
            g = self._gram(x, f"stk{stack_axis}",
                           lambda: jnp.einsum("eti,etj->eij", f, f))
            self._hess[name] = self._hess.get(name, 0.0) + g

    # -- queries -----------------------------------------------------

    def names(self) -> List[str]:
        return sorted(self._sumsq)

    def has(self, name: str) -> bool:
        return name in self._sumsq

    def norms(self, name: str) -> Array:
        return jnp.sqrt(self._sumsq[name])

    def hessian(self, name: str) -> Optional[Array]:
        return self._hess.get(name)

    def token_count(self, name: str):
        """Recorded token rows: an int for flat taps, an (E,) array of
        per-expert dispatched counts for stacked taps."""
        return self._count.get(name, 0)


@contextlib.contextmanager
def tap_capture(hessian: bool = False,
                hessian_names: Optional[set] = None):
    """Activate activation recording for the enclosed eager forward."""
    cap = TapCapture(hessian=hessian, hessian_names=hessian_names)
    _tap_captures().append(cap)
    try:
        yield cap
    finally:
        _tap_captures().remove(cap)


@contextlib.contextmanager
def tap_scope(prefix: str):
    """Push a name component: taps inside record as '<prefix>.<leaf>'."""
    stack = _tap_prefix()
    stack.append(prefix)
    try:
        yield
    finally:
        stack.pop()


def tap_active() -> bool:
    return bool(_tap_captures())


def _full_tap_name(leaf: str) -> str:
    pre = _tap_prefix()
    return ".".join(pre + [leaf]) if pre else leaf


def tap_record(leaf: str, x: Array) -> None:
    """Report a linear's input under the current scope. No-op unless a
    capture is active (the check is one empty-list test)."""
    caps = _tap_captures()
    if not caps:
        return
    name = _full_tap_name(leaf)
    for cap in caps:
        cap.record(name, x)


def tap_record_stacked(leaf: str, x: Array, stack_axis: int) -> None:
    """Per-expert variant: ``stack_axis`` indexes the expert dim."""
    caps = _tap_captures()
    if not caps:
        return
    name = _full_tap_name(leaf)
    for cap in caps:
        cap.record_stacked(name, x, stack_axis)


def is_axes_leaf(x) -> bool:
    """A logical-axes annotation: plain tuple of str/None. Excludes
    namedtuples (KVCache, MambaCache, …) which are pytree containers."""
    return (isinstance(x, tuple) and not hasattr(x, "_fields")
            and all(a is None or isinstance(a, str) for a in x))


# ------------------------------------------------------------------
# Config
# ------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """One architecture from the assignment (full or reduced)."""

    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"          # swiglu | relu2 | gelu
    rope: str = "rope"           # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (0, 0, 0)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    shared_ff: int = 0           # total d_ff of the shared-expert branch
    capacity_factor: float = 1.25
    moe_group: int = 1024        # tokens per dispatch group (sort-free MoE)
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one *shared* attention block applied every k layers
    attn_every: int = 0
    # misc
    causal: bool = True
    input_mode: str = "tokens"   # tokens | embeds (audio/vlm stub frontends)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    q_chunk: int = 512           # query-chunked attention block size
    kv_quant: bool = False       # int8 KV cache (beyond-paper serve opt)
    dtype: Any = jnp.bfloat16

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv * self.d_head

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def conv_dim(self) -> int:
        # x-branch + B + C streams go through the depthwise conv (n_groups=1)
        return self.d_inner + 2 * self.ssm_state

    def with_(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


# ------------------------------------------------------------------
# Initializers
# ------------------------------------------------------------------

def dense_init(key: Array, shape: Tuple[int, ...], in_dim: int, dtype) -> Array:
    """Truncated-normal fan-in init (LLM-standard)."""
    scale = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key: Array, shape: Tuple[int, ...], dtype) -> Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ------------------------------------------------------------------
# Norms / activations
# ------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def activation(x: Array, kind: str) -> Array:
    if kind == "silu":
        return jax.nn.silu(x)
    if kind == "gelu":
        return jax.nn.gelu(x)
    if kind == "relu2":            # nemotron-4 squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {kind}")


# ------------------------------------------------------------------
# Rotary embeddings (RoPE and Qwen2-VL M-RoPE)
# ------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x (B, S, H, dh); positions (B, S) int32. Split-half convention."""
    b, s, h, dh = x.shape
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: Array, positions: Array, theta: float,
                sections: Tuple[int, int, int]) -> Array:
    """Qwen2-VL multimodal RoPE. positions (B, S, 3) = (t, h, w) ids;
    rotary frequency groups are split across the three streams
    (sections sum to dh/2). For text tokens all three ids coincide and
    M-RoPE reduces exactly to 1-D RoPE."""
    b, s, h, dh = x.shape
    freqs = rope_freqs(dh, theta)                        # (dh/2,)
    ang3 = positions.astype(jnp.float32)[:, :, None, :] * freqs[None, None, :, None]
    # select which stream drives each frequency                        (B,S,dh/2,3)
    sec = jnp.concatenate([
        jnp.full((n,), i, jnp.int32) for i, n in enumerate(sections)])
    ang = jnp.take_along_axis(ang3, sec[None, None, :, None].astype(jnp.int32),
                              axis=-1)[..., 0]           # (B, S, dh/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg: ArchConfig, batch: int, seq: int,
                  offset: int | Array = 0) -> Array:
    """Default position ids (text stream). M-RoPE gets (B,S,3)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope == "mrope":
        return jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


def rotate(cfg: ArchConfig, x: Array, positions: Array) -> Array:
    if cfg.rope == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.rope == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x


# ------------------------------------------------------------------
# Cross-entropy (vocab-sharding friendly: logits stay (…, V))
# ------------------------------------------------------------------

def softmax_xent(logits: Array, labels: Array, mask: Optional[Array] = None
                 ) -> Array:
    """Mean next-token CE. logits (B,S,V) any float dtype, labels (B,S).

    One-hot (multiply+reduce) label pick instead of take_along_axis so a
    vocab-sharded logits tensor never gets gathered: both the logsumexp
    and the label-select lower to sharded reductions + tiny all-reduces
    under GSPMD."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    vocab_ids = jnp.arange(logits.shape[-1], dtype=jnp.int32)
    onehot = (labels[..., None].astype(jnp.int32) == vocab_ids)
    ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
