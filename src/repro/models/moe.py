"""Mixture-of-Experts layer: sort-free capacity-based top-k dispatch.

GSPMD-native design (Mesh-TF / GShard lineage): tokens are reshaped into
dispatch *groups* of ``cfg.moe_group`` tokens; gates + a within-group
running count produce a one-hot dispatch tensor (G, T, E, C) that einsums
tokens into per-expert buffers (G*? -> E, C, D). When experts are sharded
over "model" and tokens over "data", XLA lowers the two einsums to the
canonical all-to-all pair. No sorting, no dynamic shapes — TPU-friendly.

Supports shared experts (DeepSeek-MoE): a dense always-on gated MLP with
total hidden width ``cfg.shared_ff`` added to the routed output.

Auxiliary load-balancing loss (Switch-style) is returned so train steps
can weight it in.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.packed_model import ExpertPackedStack, expert_matmul
from repro.models.common import (ArchConfig, dense_init, tap_record,
                                 tap_record_stacked, tap_scope)
from repro.models import mlp as mlp_lib

Array = jax.Array


def _expert_apply(x4: Array, w) -> Array:
    """Batched per-expert linear on the post-dispatch buffer: x4
    (G, E, C, D_in) -> (G, E, C, D_out). ``w`` is either the dense
    (E, D_in, D_out) expert leaf (einsum — XLA batched matmul) or an
    ``ExpertPackedStack``, served by the grouped-expert Pallas kernels
    (one launch per expert bucket, expert index in the grid)."""
    if isinstance(w, ExpertPackedStack):
        g, e, c, d = x4.shape
        xe = x4.transpose(1, 0, 2, 3).reshape(e, g * c, d)
        y = expert_matmul(xe, w)
        return y.reshape(e, g, c, -1).transpose(1, 0, 2, 3)
    return jnp.einsum("gecd,edf->gecf", x4, w)


def moe_axes(cfg: ArchConfig) -> dict:
    axes = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "ffn"),
        "w_up": ("experts", "embed", "ffn"),
        "w_down": ("experts", "ffn", "embed"),
    }
    if cfg.shared_ff:
        axes["shared"] = mlp_lib.mlp_axes(cfg.with_(act="swiglu"))
    return axes


def init_moe(cfg: ArchConfig, key: Array):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), d, cfg.dtype),
        "w_up": dense_init(ks[2], (e, d, f), d, cfg.dtype),
        "w_down": dense_init(ks[3], (e, f, d), f, cfg.dtype),
    }
    if cfg.shared_ff:
        p["shared"], _ = mlp_lib.init_mlp(cfg.with_(act="swiglu"), ks[4],
                                          d_ff=cfg.shared_ff)
    return p, moe_axes(cfg)


def capacity(cfg: ArchConfig, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def moe_ffn(cfg: ArchConfig, p: dict, x: Array) -> Tuple[Array, Array]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    n_tok = b * s
    tpg = min(cfg.moe_group, n_tok)
    if n_tok % tpg:
        tpg = n_tok            # degenerate smoke shapes: one group
    g = n_tok // tpg
    c = capacity(cfg, tpg)

    xt = x.reshape(g, tpg, d)
    tap_record("router", xt)
    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                      # (G,T,E)

    # top-k gating: iteratively peel off the argmax k times (k is small).
    gates = jnp.zeros_like(probs)
    remaining = probs
    sel_onehot = jnp.zeros_like(probs)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        oh = jax.nn.one_hot(idx, e, dtype=probs.dtype)
        gates = gates + remaining * oh
        sel_onehot = sel_onehot + oh
        remaining = remaining * (1.0 - oh)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    # position of each token within its expert's buffer (running count)
    pos_in_expert = jnp.cumsum(sel_onehot, axis=1) - sel_onehot   # (G,T,E)
    keep = sel_onehot * (pos_in_expert < c)                       # drop overflow
    gates = gates * (jnp.sum(keep, -1, keepdims=True) > 0)

    slot = jax.nn.one_hot(pos_in_expert, c, dtype=xt.dtype)       # (G,T,E,C)
    dispatch = slot * keep[..., None].astype(xt.dtype)            # (G,T,E,C)
    combine = dispatch * gates[..., None].astype(xt.dtype)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch, xt)        # (G,E,C,D)
    # per-expert taps at the per-expert matmul site: each expert's stats
    # see exactly the dispatched-token subset it serves (capacity drops
    # included), with unused capacity slots contributing zero rows.
    tap_record_stacked("w_gate", expert_in, stack_axis=1)
    tap_record_stacked("w_up", expert_in, stack_axis=1)
    h = _expert_apply(expert_in, p["w_gate"])
    h = jax.nn.silu(h) * _expert_apply(expert_in, p["w_up"])
    tap_record_stacked("w_down", h, stack_axis=1)
    expert_out = _expert_apply(h, p["w_down"])                    # (G,E,C,D)
    y = jnp.einsum("gtec,gecd->gtd", combine, expert_out)
    y = y.reshape(b, s, d)

    # Switch load-balancing aux: E * sum_e f_e * P_e
    frac_tokens = jnp.mean(sel_onehot, axis=(0, 1)) / k           # (E,)
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)

    if cfg.shared_ff:
        with tap_scope("shared"):
            y = y + mlp_lib.mlp(cfg.with_(act="swiglu"), p["shared"], x)
    return y, aux
