"""Mamba-2 block via SSD (state-space duality, arXiv:2405.21060).

Chunked algorithm: the sequence is split into chunks of ``cfg.ssm_chunk``;
within a chunk the SSD quadratic (attention-like) form runs on the MXU,
and a lax.scan carries the (B, H, P, N) recurrent state across chunks.
Live memory is O(chunk^2) + the carried state — never O(S^2) — which is
what makes the 500k-token cells feasible.

Projections are kept *separate* (z / x / B / C / dt) rather than one fused
in_proj: each output dim then has a clean logical axis so the TP planner
can shard d_inner over "model" without slicing through a sharded dim
(numerically identical to the fused layout).

Decode is the O(1)-per-token recurrent form with a rolling depthwise-conv
window; the cache is sequence-length independent.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.packed_model import linear
from repro.models.common import ArchConfig, dense_init, rms_norm

Array = jax.Array


def mamba_axes() -> dict:
    return {
        "in_z": ("embed", "ssm"), "in_x": ("embed", "ssm"),
        "in_b": ("embed", None), "in_c": ("embed", None),
        "in_dt": ("embed", "ssm_heads"),
        "conv_x": ("ssm", None), "conv_b": (None, None), "conv_c": (None, None),
        "a_log": ("ssm_heads",), "d_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",), "gate_norm": ("ssm",),
        "out": ("ssm", "embed"),
    }


def init_mamba(cfg: ArchConfig, key: Array):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    k = cfg.ssm_conv
    ks = jax.random.split(key, 10)
    p = {
        "in_z": dense_init(ks[0], (d, di), d, cfg.dtype),
        "in_x": dense_init(ks[1], (d, di), d, cfg.dtype),
        "in_b": dense_init(ks[2], (d, n), d, cfg.dtype),
        "in_c": dense_init(ks[3], (d, n), d, cfg.dtype),
        "in_dt": dense_init(ks[4], (d, h), d, jnp.float32),
        "conv_x": dense_init(ks[5], (di, k), k, cfg.dtype),
        "conv_b": dense_init(ks[6], (n, k), k, cfg.dtype),
        "conv_c": dense_init(ks[7], (n, k), k, cfg.dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "gate_norm": jnp.ones((di,), jnp.float32),
        "out": dense_init(ks[8], (di, d), di, cfg.dtype),
    }
    return p, mamba_axes()


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv. x (B, S, C), w (C, K)."""
    b, s, c = x.shape
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_k w[:, k] * x[t - (K-1) + k]  — small K, unrolled adds.
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i:i + s, :] * w[None, None, :, i].astype(x.dtype)
    return out


def _ssd_chunk_scan(x: Array, dt: Array, a: Array, bmat: Array, cmat: Array,
                    chunk: int, h0: Array | None = None
                    ) -> Tuple[Array, Array]:
    """Chunked SSD. x (B,S,H,P), dt (B,S,H) >0, a (H,) <0,
    bmat/cmat (B,S,N). Returns (y (B,S,H,P), final state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    nc = max(s // chunk, 1)
    if s % chunk:
        chunk, nc = s, 1

    def to_chunks(t):
        return t.reshape(bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc = to_chunks(x.astype(jnp.float32)), to_chunks(dt)
    bc, cc = to_chunks(bmat.astype(jnp.float32)), to_chunks(cmat.astype(jnp.float32))

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)

    def step(hstate, inp):
        x_c, dt_c, b_c, c_c = inp            # (B,L,H,P) (B,L,H) (B,L,N) (B,L,N)
        da = dt_c * a[None, None, :]          # (B,L,H)  <= 0
        da_cum = jnp.cumsum(da, axis=1)       # (B,L,H)
        dtx = x_c * dt_c[..., None]           # (B,L,H,P)

        # intra-chunk (quadratic / attention-like form)
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)            # (B,L,L)
        diff = da_cum[:, :, None, :] - da_cum[:, None, :, :]  # (B,i,j,H)
        ii = jnp.arange(chunk)
        causal = (ii[:, None] >= ii[None, :])[None, :, :, None]
        lmat = jnp.where(causal, jnp.exp(jnp.minimum(diff, 0.0)), 0.0)
        y_diag = jnp.einsum("bij,bijh,bjhp->bihp", cb, lmat, dtx)

        # inter-chunk contribution from carried state
        y_off = jnp.einsum("bin,bhpn->bihp", c_c, hstate) * \
            jnp.exp(da_cum)[..., None]

        # state update
        total = da_cum[:, -1, :]                              # (B,H)
        decay_to_end = jnp.exp(total[:, None, :] - da_cum)    # (B,L,H)
        h_new = hstate * jnp.exp(total)[:, :, None, None] + \
            jnp.einsum("bjhp,bjn,bjh->bhpn", dtx, b_c, decay_to_end)
        return h_new, y_diag + y_off

    h_final, yc = jax.lax.scan(step, h0, (xc, dtc, bc, cc))
    y = yc.swapaxes(0, 1).reshape(bsz, s, h, p)
    return y, h_final


def mamba_block(cfg: ArchConfig, p: dict, x: Array) -> Array:
    """Full-sequence SSD block. x (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = linear(x, p["in_z"], tap="in_z")
    xs = jax.nn.silu(_causal_conv(linear(x, p["in_x"], tap="in_x"),
                                  p["conv_x"]))
    bmat = jax.nn.silu(_causal_conv(linear(x, p["in_b"], tap="in_b"),
                                    p["conv_b"]))
    cmat = jax.nn.silu(_causal_conv(linear(x, p["in_c"], tap="in_c"),
                                    p["conv_c"]))
    dt = jax.nn.softplus(x.astype(jnp.float32) @ p["in_dt"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    xh = xs.reshape(b, s, h, pd)
    y, _ = _ssd_chunk_scan(xh, dt, a, bmat, cmat, cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, cfg.d_inner).astype(cfg.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return linear(y, p["out"], tap="out")


# ------------------------------------------------------------------
# Decode path (O(1) per token)
# ------------------------------------------------------------------

class MambaCache(NamedTuple):
    conv_x: Array   # (B, K-1, d_inner) rolling window
    conv_b: Array   # (B, K-1, N)
    conv_c: Array   # (B, K-1, N)
    h: Array        # (B, H, P, N) recurrent state, f32


def init_mamba_cache(cfg: ArchConfig, batch: int) -> MambaCache:
    k = cfg.ssm_conv
    return MambaCache(
        jnp.zeros((batch, k - 1, cfg.d_inner), cfg.dtype),
        jnp.zeros((batch, k - 1, cfg.ssm_state), cfg.dtype),
        jnp.zeros((batch, k - 1, cfg.ssm_state), cfg.dtype),
        jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                  jnp.float32),
    )


def mamba_cache_axes() -> MambaCache:
    return MambaCache(("batch", None, "ssm"), ("batch", None, None),
                      ("batch", None, None), ("batch", "ssm_heads", None, None))


def _conv_step(window: Array, x_new: Array, w: Array
               ) -> Tuple[Array, Array]:
    """window (B, K-1, C), x_new (B, C) -> (new window, conv output (B, C))."""
    full = jnp.concatenate([window, x_new[:, None, :]], axis=1)  # (B, K, C)
    out = jnp.einsum("bkc,ck->bc", full, w.astype(x_new.dtype))
    return full[:, 1:, :], out


def mamba_decode_step(cfg: ArchConfig, p: dict, x: Array, cache: MambaCache
                      ) -> Tuple[Array, MambaCache]:
    """x (B, 1, D) -> (y (B, 1, D), cache')."""
    b = x.shape[0]
    xt = x[:, 0, :]
    h, pd, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z = linear(xt, p["in_z"], tap="in_z")
    wx, xconv = _conv_step(cache.conv_x, linear(xt, p["in_x"], tap="in_x"),
                           p["conv_x"])
    wb, bconv = _conv_step(cache.conv_b, linear(xt, p["in_b"], tap="in_b"),
                           p["conv_b"])
    wc, cconv = _conv_step(cache.conv_c, linear(xt, p["in_c"], tap="in_c"),
                           p["conv_c"])
    xs = jax.nn.silu(xconv).reshape(b, h, pd).astype(jnp.float32)
    bvec = jax.nn.silu(bconv).astype(jnp.float32)                 # (B, N)
    cvec = jax.nn.silu(cconv).astype(jnp.float32)                 # (B, N)
    dt = jax.nn.softplus(xt.astype(jnp.float32) @ p["in_dt"] + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                      # (H,)

    da = jnp.exp(dt * a[None, :])                                 # (B, H)
    dtx = xs * dt[..., None]                                      # (B, H, P)
    h_new = cache.h * da[:, :, None, None] + \
        dtx[..., None] * bvec[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h_new, cvec) + \
        xs * p["d_skip"][None, :, None]
    y = y.reshape(b, cfg.d_inner).astype(cfg.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return (linear(y, p["out"], tap="out")[:, None, :],
            MambaCache(wx, wb, wc, h_new))
