"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Low-rank factors follow the ops-wrapper convention: ``u`` is (N,) or
(N, R) column factors, ``v`` is (K,) or (K, R).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.packing import (ELLPacked, NMPacked, ell_unpack, unpack_nm,
                                unpack_sign_bits)

Array = jax.Array


def _cols(u: Array) -> Array:
    """(N,) -> (N, 1); (N, R) passes through."""
    return u[:, None] if u.ndim == 1 else u


def binlr_ref(x: Array, b_packed: Array, u: Array, v: Array) -> Array:
    """y = Σ_r ((x ⊙ v_r) @ Bᵀ) ⊙ u_r — binary ⊙ rank-r term."""
    k = x.shape[-1]
    b = unpack_sign_bits(b_packed, k, dtype=jnp.float32)
    uu, vv = _cols(u).astype(jnp.float32), _cols(v).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    out = jnp.zeros((*x.shape[:-1], b.shape[0]), jnp.float32)
    for r in range(uu.shape[1]):
        out = out + ((xf * vv[:, r]) @ b.T) * uu[:, r]
    return out


def lowrank_ref(x: Array, u: Array, v: Array) -> Array:
    """y = (x @ V) @ Uᵀ — rank-r low-rank term, no binary."""
    uu, vv = _cols(u).astype(jnp.float32), _cols(v).astype(jnp.float32)
    return (x.astype(jnp.float32) @ vv) @ uu.T


def nm_matmul_ref(x: Array, vals: Array, idx: Array, m: int) -> Array:
    """y = x @ W_Sᵀ with W_S in N:M packed form."""
    n = vals.shape[-1]
    d_in = vals.shape[1] * m
    w = unpack_nm(NMPacked(vals, idx, n, m, d_in))
    return x.astype(jnp.float32) @ w.astype(jnp.float32).T


def ell_matmul_ref(x: Array, vals: Array, idx: Array, d_in: int) -> Array:
    """y = x @ W_Sᵀ with W_S in row-padded ELL form."""
    w = ell_unpack(ELLPacked(vals, idx, d_in))
    return x.astype(jnp.float32) @ w.astype(jnp.float32).T


def ell_lr_matmul_ref(x: Array, vals: Array, idx: Array, d_in: int,
                      u: Array, v: Array) -> Array:
    """ELL sparse + rank-r low-rank, no binary."""
    return ell_matmul_ref(x, vals, idx, d_in) + lowrank_ref(x, u, v)


def slab_ell_matmul_ref(x: Array, vals: Array, idx: Array, d_in: int,
                        b_packed: Array, u: Array, v: Array) -> Array:
    """Fused SLaB linear with ELL sparse part."""
    return ell_matmul_ref(x, vals, idx, d_in) + binlr_ref(x, b_packed, u, v)


def slab_matmul_ref(x: Array, w_s: Array, b_packed: Array,
                    u: Array, v: Array) -> Array:
    """Fused SLaB linear, dense-masked sparse part:
    y = x @ W_Sᵀ + Σ_r ((x ⊙ v_r) @ Bᵀ) ⊙ u_r."""
    y = x.astype(jnp.float32) @ w_s.astype(jnp.float32).T
    return y + binlr_ref(x, b_packed, u, v)


def slab_nm_matmul_ref(x: Array, vals: Array, idx: Array, m: int,
                       b_packed: Array, u: Array, v: Array) -> Array:
    """Fused SLaB linear with N:M packed sparse part."""
    return nm_matmul_ref(x, vals, idx, m) + binlr_ref(x, b_packed, u, v)


def slab_lr_matmul_ref(x: Array, w_s: Array, u: Array, v: Array) -> Array:
    """Sparse + rank-r low-rank, no binary: y = x @ W_Sᵀ + (x @ V) @ Uᵀ."""
    y = x.astype(jnp.float32) @ w_s.astype(jnp.float32).T
    return y + lowrank_ref(x, u, v)


def slab_nm_lr_matmul_ref(x: Array, vals: Array, idx: Array, m: int,
                          u: Array, v: Array) -> Array:
    """N:M sparse + rank-r low-rank, no binary."""
    return nm_matmul_ref(x, vals, idx, m) + lowrank_ref(x, u, v)


def flash_decode_ref(q: Array, k: Array, v: Array, lengths: Array,
                     k_scale: Array | None = None,
                     v_scale: Array | None = None) -> Array:
    """Grouped decode attention oracle. q (B,KV,G,dh) pre-scaled;
    k/v (B,S,KV,dh); lengths (B,). Returns (B,KV,G,dh)."""
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale.astype(jnp.float32)[..., None]
        vf = vf * v_scale.astype(jnp.float32)[..., None]
    logits = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32), kf)
    pos = jnp.arange(k.shape[1])
    mask = pos[None, :] < lengths[:, None]                  # (B, S)
    logits = jnp.where(mask[:, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p, vf).astype(q.dtype)
