"""Pallas TPU kernel: flash-decode — grouped-query single-token
attention against a (possibly int8-quantized) KV cache.

    out (B, KV, G, dh) = softmax(q · Kᵀ / √dh) · V     per (batch, kv head)

Grid (B, KV, S/bs): each step streams one (bs, dh) K/V chunk HBM→VMEM,
updates an online-softmax accumulator in VMEM scratch (running max m,
normalizer l, weighted sum acc), and writes the normalized output on
the last chunk. The (S,) score row is never materialized in HBM —
exactly the flash-attention trick in its decode form, which is what the
GSPMD path approximates with the "kv_seq over model" sharding.

int8 mode: K/V chunks arrive as int8 + per-(token, head) scales; the
dequant multiply happens in VMEM on the chunk only (the HBM stream is
the 1-byte payload — 2x less than bf16, the §Perf A2 term).

Valid-length masking uses a scalar-prefetch length per batch row
(cache slots beyond `length` are ignored).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, n_s: int, quant: bool):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (bs, dh)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    # mask positions beyond the valid cache length
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)          # (G, bs)

    m_prev = m_ref[...]                                 # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                         # (G, bs)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: Array, k: Array, v: Array, lengths: Array,
                 k_scale: Array | None = None,
                 v_scale: Array | None = None,
                 *, bs: int = 512, interpret: bool = False) -> Array:
    """q (B, KV, G, dh) pre-scaled by 1/sqrt(dh); k/v (B, S, KV, dh)
    [int8 when scales given, with k_scale/v_scale (B, S, KV)];
    lengths (B,) int32. Returns (B, KV, G, dh)."""
    b, kv, g, dh = q.shape
    s = k.shape[1]
    bs = min(bs, s)
    assert s % bs == 0, (s, bs)
    quant = k_scale is not None
    if not quant:       # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((b, s, kv), jnp.float32)
        v_scale = jnp.ones((b, s, kv), jnp.float32)

    grid = (b, kv, s // bs)
    kernel = functools.partial(_kernel, bs=bs, n_s=grid[2], quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bb, kk, ss, lens: (bb, kk, 0, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bb, kk, ss, lens: (bb, ss, kk, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bb, kk, ss, lens: (bb, ss, kk, 0)),
            pl.BlockSpec((1, bs, 1), lambda bb, kk, ss, lens: (bb, ss, kk)),
            pl.BlockSpec((1, bs, 1), lambda bb, kk, ss, lens: (bb, ss, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bb, kk, ss, lens: (bb, kk, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v, k_scale, v_scale)
