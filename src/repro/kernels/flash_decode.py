"""Pallas TPU kernel: flash-decode — grouped-query single-token
attention against a (possibly int8-quantized) KV cache.

    out (B, KV, G, dh) = softmax(q · Kᵀ / √dh) · V     per (batch, kv head)

Grid (B, KV, S/bs): each step streams one (bs, dh) K/V chunk HBM→VMEM,
updates an online-softmax accumulator in VMEM scratch (running max m,
normalizer l, weighted sum acc), and writes the normalized output on
the last chunk. The (S,) score row is never materialized in HBM —
exactly the flash-attention trick in its decode form, which is what the
GSPMD path approximates with the "kv_seq over model" sharding.

int8 mode: K/V chunks arrive as int8 + per-(token, head) scales; the
dequant multiply happens in VMEM on the chunk only (the HBM stream is
the 1-byte payload — 2x less than bf16, the §Perf A2 term).

Valid-length masking uses a scalar-prefetch length per batch row
(cache slots beyond `length` are ignored).

``flash_decode_paged`` is the block-table variant for the paged KV
cache (`repro.serving.paged_cache`): K/V live in a global block pool
(n_blocks, bs, KV, dh) and each request owns a row of *logical→physical*
block indices. The same online-softmax kernel runs, but the K/V
BlockSpec index maps read the physical block id from a scalar-prefetched
block table — chunk ``ss`` of request ``bb`` streams pool block
``block_tables[bb, ss]``. Chunks past the request's valid length are
skipped (`pl.when`), so decode work is proportional to each request's
actual cache length, not the table width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_ref, l_ref, acc_ref, *, bs: int, n_s: int, quant: bool):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                 # (G, dh)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (bs, dh)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (bs, dh)
    if quant:
        k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
        v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    # mask positions beyond the valid cache length
    pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
    valid = pos < len_ref[b]
    scores = jnp.where(valid, scores, NEG_INF)          # (G, bs)

    m_prev = m_ref[...]                                 # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                         # (G, bs)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: Array, k: Array, v: Array, lengths: Array,
                 k_scale: Array | None = None,
                 v_scale: Array | None = None,
                 *, bs: int = 512, interpret: bool = False) -> Array:
    """q (B, KV, G, dh) pre-scaled by 1/sqrt(dh); k/v (B, S, KV, dh)
    [int8 when scales given, with k_scale/v_scale (B, S, KV)];
    lengths (B,) int32. Returns (B, KV, G, dh)."""
    b, kv, g, dh = q.shape
    s = k.shape[1]
    bs = min(bs, s)
    if s % bs:
        # pad the trailing chunk instead of asserting: padded slots sit
        # at positions >= s >= lengths, so the existing valid-length
        # mask already excludes them from the softmax
        pad = (-s) % bs
        padded = ((0, 0), (0, pad), (0, 0))
        k = jnp.pad(k, padded + ((0, 0),))
        v = jnp.pad(v, padded + ((0, 0),))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, padded)
            v_scale = jnp.pad(v_scale, padded)
        s += pad
    quant = k_scale is not None
    if not quant:       # dummy scale operands keep one kernel signature
        k_scale = jnp.ones((b, s, kv), jnp.float32)
        v_scale = jnp.ones((b, s, kv), jnp.float32)

    grid = (b, kv, s // bs)
    kernel = functools.partial(_kernel, bs=bs, n_s=grid[2], quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bb, kk, ss, lens: (bb, kk, 0, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bb, kk, ss, lens: (bb, ss, kk, 0)),
            pl.BlockSpec((1, bs, 1, dh), lambda bb, kk, ss, lens: (bb, ss, kk, 0)),
            pl.BlockSpec((1, bs, 1), lambda bb, kk, ss, lens: (bb, ss, kk)),
            pl.BlockSpec((1, bs, 1), lambda bb, kk, ss, lens: (bb, ss, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bb, kk, ss, lens: (bb, kk, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv, g, dh), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v, k_scale, v_scale)


# ------------------------------------------------------------------
# Paged (block-table) variant
# ------------------------------------------------------------------

def _paged_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref,
                  *, bs: int, n_s: int, quant: bool):
    b = pl.program_id(0)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # chunks wholly past this request's cache length carry no valid
    # tokens — skip the dot-products (work ∝ actual length, not table
    # width; a zero-length request touches no chunk at all)
    @pl.when(s * bs < len_ref[b])
    def _accumulate():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, dh)
        k = k_ref[0, :, 0].astype(jnp.float32)          # (bs, dh)
        v = v_ref[0, :, 0].astype(jnp.float32)          # (bs, dh)
        if quant:
            k = k * ks_ref[0, :, 0].astype(jnp.float32)[:, None]
            v = v * vs_ref[0, :, 0].astype(jnp.float32)[:, None]

        scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                     preferred_element_type=jnp.float32)
        pos = s * bs + jax.lax.broadcasted_iota(jnp.int32, (1, bs), 1)
        valid = pos < len_ref[b]
        scores = jnp.where(valid, scores, NEG_INF)      # (G, bs)

        m_prev = m_ref[...]                             # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                     # (G, bs)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(s == n_s - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_paged(q: Array, k_pool: Array, v_pool: Array,
                       block_tables: Array, lengths: Array,
                       k_scale: Array | None = None,
                       v_scale: Array | None = None,
                       *, interpret: bool = False) -> Array:
    """Flash-decode against a paged KV cache.

    q (R, KV, G, dh) pre-scaled by 1/sqrt(dh); k_pool/v_pool
    (n_blocks, bs, KV, dh) [int8 when scales given, with k_scale/v_scale
    (n_blocks, bs, KV)]; block_tables (R, n_bt) int32 physical block ids
    per logical chunk (entries past a request's length may hold
    anything in range — they are never read); lengths (R,) int32 valid
    tokens per request. Returns (R, KV, G, dh); zero-length rows
    return zeros."""
    r, kv, g, dh = q.shape
    n_blocks, bs = k_pool.shape[0], k_pool.shape[1]
    n_bt = block_tables.shape[1]
    quant = k_scale is not None
    if not quant:
        k_scale = jnp.ones((n_blocks, bs, kv), jnp.float32)
        v_scale = jnp.ones((n_blocks, bs, kv), jnp.float32)

    grid = (r, kv, n_bt)
    kernel = functools.partial(_paged_kernel, bs=bs, n_s=n_bt, quant=quant)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,        # block_tables, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh),
                         lambda bb, kk, ss, bt, lens: (bb, kk, 0, 0)),
            # chunk ss of request bb streams physical pool block
            # bt[bb, ss] — the paged indirection lives entirely in the
            # scalar-prefetched index map
            pl.BlockSpec((1, bs, 1, dh),
                         lambda bb, kk, ss, bt, lens: (bt[bb, ss], 0, kk, 0)),
            pl.BlockSpec((1, bs, 1, dh),
                         lambda bb, kk, ss, bt, lens: (bt[bb, ss], 0, kk, 0)),
            pl.BlockSpec((1, bs, 1),
                         lambda bb, kk, ss, bt, lens: (bt[bb, ss], 0, kk)),
            pl.BlockSpec((1, bs, 1),
                         lambda bb, kk, ss, bt, lens: (bt[bb, ss], 0, kk)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh),
                               lambda bb, kk, ss, bt, lens: (bb, kk, 0, 0)),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, dh), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((r, kv, g, dh), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, q, k_pool, v_pool, k_scale, v_scale)
