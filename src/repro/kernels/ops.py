"""Jit'd public wrappers for the SLaB Pallas kernels.

Handles shape padding to block multiples, dtype plumbing, the
interpret-mode switch (CPU validation; compiled Mosaic on real TPU), and
a `slab_linear_kernel` convenience that consumes a `SLaBPacked` bundle.

Low-rank factors are accepted in any of the storage conventions —
``u``: (N,) rank-1 vector or (N, R) column factors; ``v``: (K,) or
(K, R) — and canonicalized to the kernels' row-major rank stacks
(R, N) / (R, K).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.packing import NMPacked, SLaBPacked
from repro.kernels import binlr as binlr_k
from repro.kernels import ell as ell_k
from repro.kernels import nm_sparse as nm_k
from repro.kernels import slab_matmul as slab_k

Array = jax.Array


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _pad_rows(x: Array, mult: int) -> Array:
    m = x.shape[0]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


def _rank_stack(u: Array, v: Array):
    """(N,)/(N,R) u and (K,)/(K,R) v -> kernel-layout (R,N), (R,K)."""
    u2 = u[None, :] if u.ndim == 1 else u.T
    v2 = v[None, :] if v.ndim == 1 else v.T
    return u2, v2


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def binlr(x: Array, b_packed: Array, u: Array, v: Array,
          bm: int = 256, bn: int = 256, bk: int = 512,
          interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = binlr_k.binlr_matmul(x2, b_packed, u, v, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def nm_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
              bm: int = 256, bn: int = 256, bk: int = 512,
              interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = nm_k.nm_matmul(x2, vals, idx, m_pat, bm=bm, bn=bn, bk=bk,
                       interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def slab_matmul(x: Array, w_s: Array, b_packed: Array, u: Array, v: Array,
                bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = slab_k.slab_matmul(x2, w_s, b_packed, u, v, bm=bm, bn=bn, bk=bk,
                           interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def slab_nm_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
                   b_packed: Array, u: Array, v: Array,
                   bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = slab_k.slab_nm_matmul(x2, vals, idx, m_pat, b_packed, u, v,
                              bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def slab_lr_matmul(x: Array, w_s: Array, u: Array, v: Array,
                   bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: Optional[bool] = None) -> Array:
    """Fused sparse + rank-r low-rank linear with NO binary term
    (HASSLE-free-style decompositions): y = x @ W_Sᵀ + (x @ V) @ Uᵀ."""
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = slab_k.slab_lr_matmul(x2, w_s, u, v, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def slab_nm_lr_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
                      u: Array, v: Array,
                      bm: int = 256, bn: int = 256, bk: int = 512,
                      interpret: Optional[bool] = None) -> Array:
    """N:M sparse + rank-r low-rank, no binary term."""
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = slab_k.slab_nm_lr_matmul(x2, vals, idx, m_pat, u, v,
                                 bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ell_matmul(x: Array, vals: Array, idx: Array,
               bm: int = 128, bn: int = 256,
               interpret: Optional[bool] = None) -> Array:
    """Row-padded ELL unstructured-sparse matmul (gather-matmul kernel)."""
    interpret = _on_cpu() if interpret is None else interpret
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = ell_k.ell_matmul(x2, vals, idx, bm=bm, bn=bn, interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ell_lr_matmul(x: Array, vals: Array, idx: Array, u: Array, v: Array,
                  bm: int = 128, bn: int = 256,
                  interpret: Optional[bool] = None) -> Array:
    """ELL sparse + rank-r low-rank, no binary term."""
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = ell_k.ell_lr_matmul(x2, vals, idx, u, v, bm=bm, bn=bn,
                            interpret=interpret)
    return y[:m].reshape(*lead, -1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def slab_ell_matmul(x: Array, vals: Array, idx: Array, b_packed: Array,
                    u: Array, v: Array,
                    bm: int = 128, bn: int = 256,
                    interpret: Optional[bool] = None) -> Array:
    """Full SLaB linear with ELL sparse part + binary ⊙ rank-r term."""
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack(u, v)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    m = x2.shape[0]
    x2 = _pad_rows(x2, min(bm, max(m, 1)))
    y = ell_k.slab_ell_matmul(x2, vals, idx, b_packed, u, v, bm=bm, bn=bn,
                              interpret=interpret)
    return y[:m].reshape(*lead, -1)


# ------------------- grouped-expert (MoE) wrappers ---------------------
#
# x carries a leading expert dim (E, M, K) — the flattened post-dispatch
# capacity buffer — and every weight plane is expert-stacked. Token
# padding happens on axis 1; the expert axis is never padded (one grid
# step per expert).

def _pad_tokens_g(x: Array, mult: int) -> Array:
    m = x.shape[1]
    pad = (-m) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    return x


def _rank_stack_g(u: Array, v: Array):
    """Expert-stacked (E,N,R) u / (E,K,R) v -> kernel layout (E,R,N) /
    (E,R,K)."""
    return u.transpose(0, 2, 1), v.transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ell_matmul_g(x: Array, vals: Array, idx: Array,
                 bm: int = 128, bn: int = 256,
                 interpret: Optional[bool] = None) -> Array:
    """Grouped-expert ELL matmul: x (E, M, K), vals/idx (E, N, K_max)."""
    interpret = _on_cpu() if interpret is None else interpret
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.ell_matmul_g(x2, vals, idx, bm=bm, bn=bn, interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def ell_lr_matmul_g(x: Array, vals: Array, idx: Array, u: Array, v: Array,
                    bm: int = 128, bn: int = 256,
                    interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.ell_lr_matmul_g(x2, vals, idx, u, v, bm=bm, bn=bn,
                            interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def slab_ell_matmul_g(x: Array, vals: Array, idx: Array, b_packed: Array,
                      u: Array, v: Array,
                      bm: int = 128, bn: int = 256,
                      interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.slab_ell_matmul_g(x2, vals, idx, b_packed, u, v, bm=bm, bn=bn,
                              interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def nm_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.nm_matmul_g(x2, vals, idx, m_pat, bm=bm, bn=bn, bk=bk,
                        interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def slab_matmul_g(x: Array, w_s: Array, b_packed: Array, u: Array, v: Array,
                  bm: int = 256, bn: int = 256, bk: int = 512,
                  interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.slab_matmul_g(x2, w_s, b_packed, u, v, bm=bm, bn=bn, bk=bk,
                          interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def slab_nm_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                     b_packed: Array, u: Array, v: Array,
                     bm: int = 256, bn: int = 256, bk: int = 512,
                     interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.slab_nm_matmul_g(x2, vals, idx, m_pat, b_packed, u, v,
                             bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def slab_lr_matmul_g(x: Array, w_s: Array, u: Array, v: Array,
                     bm: int = 256, bn: int = 256, bk: int = 512,
                     interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.slab_lr_matmul_g(x2, w_s, u, v, bm=bm, bn=bn, bk=bk,
                             interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit,
                   static_argnames=("m_pat", "bm", "bn", "bk", "interpret"))
def slab_nm_lr_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                        u: Array, v: Array,
                        bm: int = 256, bn: int = 256, bk: int = 512,
                        interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.slab_nm_lr_matmul_g(x2, vals, idx, m_pat, u, v,
                                bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:, :m]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def binlr_g(x: Array, b_packed: Array, u: Array, v: Array,
            bm: int = 256, bn: int = 256, bk: int = 512,
            interpret: Optional[bool] = None) -> Array:
    interpret = _on_cpu() if interpret is None else interpret
    u, v = _rank_stack_g(u, v)
    m = x.shape[1]
    x2 = _pad_tokens_g(x, min(bm, max(m, 1)))
    from repro.kernels import grouped as g_k
    y = g_k.binlr_matmul_g(x2, b_packed, u, v, bm=bm, bn=bn, bk=bk,
                           interpret=interpret)
    return y[:, :m]


def flash_decode_attention(q: Array, k: Array, v: Array, lengths: Array,
                           k_scale: Optional[Array] = None,
                           v_scale: Optional[Array] = None,
                           bs: int = 512,
                           interpret: Optional[bool] = None) -> Array:
    """Grouped-query decode attention (optionally int8 KV) via the
    flash-decode kernel. q (B, KV, G, dh) pre-scaled by 1/sqrt(dh)."""
    from repro.kernels.flash_decode import flash_decode
    interpret = _on_cpu() if interpret is None else interpret
    return flash_decode(q, k, v, lengths, k_scale, v_scale, bs=bs,
                        interpret=interpret)


def flash_decode_paged_attention(q: Array, k_pool: Array, v_pool: Array,
                                 block_tables: Array, lengths: Array,
                                 k_scale: Optional[Array] = None,
                                 v_scale: Optional[Array] = None,
                                 interpret: Optional[bool] = None) -> Array:
    """Paged (block-table) grouped-query decode attention. q (R, KV, G,
    dh) pre-scaled; k_pool/v_pool (n_blocks, bs, KV, dh);
    block_tables (R, n_bt); lengths (R,) — zero-length rows return 0."""
    from repro.kernels.flash_decode import flash_decode_paged
    interpret = _on_cpu() if interpret is None else interpret
    return flash_decode_paged(q, k_pool, v_pool, block_tables, lengths,
                              k_scale, v_scale, interpret=interpret)


def slab_linear_kernel(x: Array, packed: SLaBPacked, **kw) -> Array:
    """Forward one SLaB-compressed linear from its packed bundle via the
    fused kernel (N:M if the sparse part is N:M packed, else dense)."""
    if isinstance(packed.sparse, NMPacked):
        s = packed.sparse
        return slab_nm_matmul(x, s.values, s.indices, s.m,
                              packed.b_packed, packed.u, packed.v, **kw)
    w_s = packed.sparse if isinstance(packed.sparse, jax.Array) else None
    if w_s is None:
        from repro.core.packing import ell_unpack
        w_s = ell_unpack(packed.sparse)
    return slab_matmul(x, w_s.astype(x.dtype), packed.b_packed,
                       packed.u, packed.v, **kw)
