"""Pallas TPU kernel: N:M semi-structured sparse matmul.

    y (M, N) = x @ W_Sᵀ,  W_S streamed as (values (N, K/m, n), idx int8)

2:4 at b=16 streams 9/16ths of the dense bytes (values + 2-bit indices,
int8-stored); the dense tile is rebuilt in VMEM by comparison-one-hot
expand (no scatter/gather — VPU compares only), then hits the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import expand_nm_tile

Array = jax.Array


def _kernel(x_ref, val_ref, idx_ref, o_ref, acc_ref, *, n_k: int, m_pat: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]                                        # (bm, bk)
    w = expand_nm_tile(val_ref[...], idx_ref[...], m_pat, x.dtype)  # (bn, bk)
    acc_ref[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def nm_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
              *, bm: int = 256, bn: int = 256, bk: int = 512,
              interpret: bool = False) -> Array:
    """x (M, K); vals/idx (N, K/m, n) -> (M, N)."""
    m, k = x.shape
    n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k, (vals.shape, m_pat, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % m_pat == 0
    bg = bk // m_pat

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel, n_k=grid[2], m_pat=m_pat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx)
