"""Shared in-VMEM unpack helpers for the SLaB Pallas kernels.

TPU adaptation (DESIGN.md §3): there is no XNOR-popcount datapath on the
MXU, so the binary matrix is *packed for bandwidth* (1 bit/elt in HBM)
and expanded to ±1 tiles in VMEM by VPU shift/mask ops; the MXU then
consumes dense bf16/f32 tiles. Same pattern for N:M sparse values:
(values, 2-bit indices) stream from HBM, a comparison-one-hot expand
rebuilds the dense tile in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def unpack_bits_tile(packed: Array, dtype) -> Array:
    """(bn, bk/32) uint32 -> (bn, bk) ±1 in ``dtype``.

    Bit-test via precomputed per-lane masks (packed & (1<<j)) != 0 then a
    single select — one AND + compare + select per element, no variable
    shifts or integer arithmetic. ~2x faster than the shift/mul form in
    interpret mode and the same VPU op class on TPU."""
    bn, words = packed.shape
    masks = jnp.uint32(1) << jax.lax.broadcasted_iota(jnp.uint32,
                                                      (1, 1, 32), 2)
    pos = (packed[:, :, None] & masks) != 0
    pm1 = jnp.where(pos, jnp.ones((), dtype), -jnp.ones((), dtype))
    return pm1.reshape(bn, words * 32)


def accum_binlr_terms(acc, x, b, u_ref, v_ref, rank: int) -> None:
    """acc += Σ_r ((x ⊙ v_r) @ Bᵀ) ⊙ u_r for one (bm, bk) x tile and an
    already-expanded ±1 tile b (bn, bk); u_ref/v_ref hold (rank, bn) /
    (rank, bk) blocks. The Python loop over ranks unrolls at trace
    time; every term reuses the one expanded B tile, so extra ranks
    cost MXU passes, not HBM bytes. u_r is constant along K, so folding
    it into each step equals scaling once at the end."""
    for r in range(rank):
        xv = x * v_ref[r:r + 1, :]
        acc[...] += (jax.lax.dot_general(
            xv, b.astype(xv.dtype), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
            * u_ref[r:r + 1, :].astype(jnp.float32))


def accum_lowrank_proj(acc_p, x, v_ref) -> None:
    """acc_p (bm, R) += x @ v_blockᵀ for one K step of the no-binary
    low-rank kernels (v_ref holds an (R, bk) block); fp32 MXU pass."""
    acc_p[...] += jax.lax.dot_general(
        x.astype(jnp.float32), v_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def lowrank_epilogue(acc, acc_p, u_ref) -> Array:
    """Final-K-step combine of the no-binary kernels: sparse accumulator
    plus the rank-R projection applied through the (R, bn) U block."""
    return acc[...] + jax.lax.dot_general(
        acc_p[...], u_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def expand_nm_tile(vals: Array, idx: Array, m: int, dtype) -> Array:
    """(bn, g, n) values + (bn, g, n) int8 positions -> dense (bn, g*m).

    Comparison one-hot expand: dense[o, g, p] = Σ_j vals[o,g,j]·[idx==p].
    No scatter — pure VPU compares/multiplies, MXU-friendly output.
    """
    bn, g, n = vals.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, m), 3)
    hit = (idx[:, :, :, None].astype(jnp.int32) == pos)
    dense = jnp.sum(jnp.where(hit, vals[:, :, :, None].astype(dtype), 0), axis=2)
    return dense.reshape(bn, g * m)
