"""Shared in-VMEM unpack helpers for the SLaB Pallas kernels.

TPU adaptation (DESIGN.md §3): there is no XNOR-popcount datapath on the
MXU, so the binary matrix is *packed for bandwidth* (1 bit/elt in HBM)
and expanded to ±1 tiles in VMEM by VPU shift/mask ops; the MXU then
consumes dense bf16/f32 tiles. Same pattern for N:M sparse values:
(values, 2-bit indices) stream from HBM, a comparison-one-hot expand
rebuilds the dense tile in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def unpack_bits_tile(packed: Array, dtype) -> Array:
    """(bn, bk/32) uint32 -> (bn, bk) ±1 in ``dtype`` (VPU shift/mask)."""
    bn, words = packed.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint32, (1, 1, 32), 2)
    bits = (packed[:, :, None] >> shifts) & jnp.uint32(1)
    pm1 = (2 * bits.astype(jnp.int32) - 1).astype(dtype)
    return pm1.reshape(bn, words * 32)


def expand_nm_tile(vals: Array, idx: Array, m: int, dtype) -> Array:
    """(bn, g, n) values + (bn, g, n) int8 positions -> dense (bn, g*m).

    Comparison one-hot expand: dense[o, g, p] = Σ_j vals[o,g,j]·[idx==p].
    No scatter — pure VPU compares/multiplies, MXU-friendly output.
    """
    bn, g, n = vals.shape
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, m), 3)
    hit = (idx[:, :, :, None].astype(jnp.int32) == pos)
    dense = jnp.sum(jnp.where(hit, vals[:, :, :, None].astype(dtype), 0), axis=2)
    return dense.reshape(bn, g * m)
