"""Pallas TPU kernels: the fused SLaB-family compressed linears.

    y = x @ W_Sᵀ + Σ_r ((x ⊙ v_r) @ Bᵀ) ⊙ u_r        (binary + rank-r)
    y = x @ W_Sᵀ + (x @ Vᵀ) @ U                      (no binary, rank-r)

One pass over K per output tile: every term shares the streamed x tile,
so x is read once (vs once per term for separate matmuls) and y is
written once. All accumulation is fp32 in VMEM scratch. The low-rank
factors arrive as row-major rank stacks u (R, N) / v (R, K) — R is
static and small (paper default 1; HASSLE-free-style decompositions use
r ≤ 16) — and the binary⊙rank-r identity

    (U Vᵀ ⊙ B) x = Σ_r u_r ⊙ (B (v_r ⊙ x))

lets the kernel accumulate r rank-1 binary terms against ONE streamed B
tile. Four variants:

  slab_matmul      — W_S dense-masked (unstructured sparsity) + binary.
  slab_nm_matmul   — W_S in N:M packed form + binary (the roofline win).
  slab_lr_matmul   — W_S dense-masked + rank-r low-rank, NO binary term
                     (HASSLE-free / SoLA-style decs): the low-rank path
                     accumulates x @ Vᵀ (bm, R) per K step and applies U
                     once on the last step — no B bytes, no ±1 expand.
  slab_nm_lr_matmul— N:M W_S + rank-r low-rank, no binary.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (accum_binlr_terms, accum_lowrank_proj,
                                  expand_nm_tile, lowrank_epilogue,
                                  unpack_bits_tile)

Array = jax.Array


# ------------------------- dense-masked W_S -------------------------

def _kernel_dense(x_ref, ws_ref, bp_ref, u_ref, v_ref, o_ref,
                  acc, *, n_k: int, rank: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...]
    acc[...] += jax.lax.dot_general(
        x, ws_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    b = unpack_bits_tile(bp_ref[...], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref, v_ref, rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def slab_matmul(x: Array, w_s: Array, b_packed: Array, u: Array, v: Array,
                *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool = False) -> Array:
    """x (M,K); w_s (N,K); b_packed (N,K/32); u (R,N); v (R,K) -> (M,N)."""
    m, k = x.shape
    n = w_s.shape[0]
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_dense, n_k=grid[2], rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((rank, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_s, b_packed, u, v)


# --------------------------- N:M packed W_S --------------------------

def _kernel_nm(x_ref, val_ref, idx_ref, bp_ref, u_ref, v_ref, o_ref,
               acc, *, n_k: int, m_pat: int, rank: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...]
    w = expand_nm_tile(val_ref[...], idx_ref[...], m_pat, x.dtype)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    b = unpack_bits_tile(bp_ref[...], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref, v_ref, rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)


def slab_nm_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
                   b_packed: Array, u: Array, v: Array,
                   *, bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = False) -> Array:
    """N:M variant. vals/idx (N, K/m, n); u (R, N); v (R, K)."""
    m, k = x.shape
    n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert (m % bm == 0 and n % bn == 0 and k % bk == 0
            and bk % 32 == 0 and bk % m_pat == 0)
    bg = bk // m_pat

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm, n_k=grid[2], m_pat=m_pat,
                               rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((rank, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx, b_packed, u, v)


# ------------------- sparse + low-rank, no binary --------------------
#
# y = x @ W_Sᵀ + (x @ Vᵀ) @ U.  The low-rank term accumulates the
# projection p = x @ Vᵀ (bm, R) across K steps and applies the (R, bn)
# U tile once on the last step — one skinny MXU pass per K step plus
# one tiny (bm,R)@(R,bn) epilogue, no binary bytes at all.

def _kernel_dense_lr(x_ref, ws_ref, u_ref, v_ref, o_ref,
                     acc, acc_p, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_p[...] = jnp.zeros_like(acc_p)

    x = x_ref[...]
    acc[...] += jax.lax.dot_general(
        x, ws_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    accum_lowrank_proj(acc_p, x, v_ref)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = lowrank_epilogue(acc, acc_p, u_ref).astype(o_ref.dtype)


def slab_lr_matmul(x: Array, w_s: Array, u: Array, v: Array,
                   *, bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = False) -> Array:
    """x (M,K); w_s (N,K); u (R,N); v (R,K) -> (M,N). No binary term."""
    m, k = x.shape
    n = w_s.shape[0]
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_dense_lr, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((rank, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rank), jnp.float32)],
        interpret=interpret,
    )(x, w_s, u, v)


def _kernel_nm_lr(x_ref, val_ref, idx_ref, u_ref, v_ref, o_ref,
                  acc, acc_p, *, n_k: int, m_pat: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_p[...] = jnp.zeros_like(acc_p)

    x = x_ref[...]
    w = expand_nm_tile(val_ref[...], idx_ref[...], m_pat, x.dtype)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    accum_lowrank_proj(acc_p, x, v_ref)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = lowrank_epilogue(acc, acc_p, u_ref).astype(o_ref.dtype)


def slab_nm_lr_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
                      u: Array, v: Array,
                      *, bm: int = 256, bn: int = 256, bk: int = 512,
                      interpret: bool = False) -> Array:
    """N:M sparse + rank-r low-rank, no binary. vals/idx (N, K/m, n)."""
    m, k = x.shape
    n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % m_pat == 0
    bg = bk // m_pat

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm_lr, n_k=grid[2], m_pat=m_pat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((rank, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rank), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx, u, v)
