"""Pallas TPU kernel: the fused SLaB compressed linear.

    y = x @ W_Sᵀ + ((x ⊙ v) @ Bᵀ) ⊙ u

One pass over K per output tile: both terms share the streamed x tile,
so x is read once (vs twice for two separate matmuls) and y is written
once. Two fp32 VMEM accumulators keep the terms separate until the final
K step (u scales only the binary term). Two variants:

  slab_matmul     — W_S dense-masked bf16 (unstructured sparsity; HBM
                    saving comes from the B term only: 17/32 of dense).
  slab_nm_matmul  — W_S in N:M packed form (2:4 streams ~9/16 for the
                    sparse term + 1/16 binary + rank-1 vectors ≈ 0.63×
                    dense bytes at 50% CR; the roofline win at decode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import expand_nm_tile, unpack_bits_tile

Array = jax.Array


# ------------------------- dense-masked W_S -------------------------

def _kernel_dense(x_ref, ws_ref, bp_ref, u_ref, v_ref, o_ref,
                  acc_s, acc_b, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        acc_b[...] = jnp.zeros_like(acc_b)

    x = x_ref[...]
    acc_s[...] += jax.lax.dot_general(
        x, ws_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xv = x * v_ref[...]
    b = unpack_bits_tile(bp_ref[...], x.dtype)
    acc_b[...] += jax.lax.dot_general(
        xv, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_s[...] +
                      acc_b[...] * u_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def slab_matmul(x: Array, w_s: Array, b_packed: Array, u: Array, v: Array,
                *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool = False) -> Array:
    """x (M,K); w_s (N,K); b_packed (N,K/32); u (N,); v (K,) -> (M,N)."""
    m, k = x.shape
    n = w_s.shape[0]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_dense, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_s, b_packed, u.reshape(1, n), v.reshape(1, k))


# --------------------------- N:M packed W_S --------------------------

def _kernel_nm(x_ref, val_ref, idx_ref, bp_ref, u_ref, v_ref, o_ref,
               acc_s, acc_b, *, n_k: int, m_pat: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s)
        acc_b[...] = jnp.zeros_like(acc_b)

    x = x_ref[...]
    w = expand_nm_tile(val_ref[...], idx_ref[...], m_pat, x.dtype)
    acc_s[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    xv = x * v_ref[...]
    b = unpack_bits_tile(bp_ref[...], x.dtype)
    acc_b[...] += jax.lax.dot_general(
        xv, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_s[...] +
                      acc_b[...] * u_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def slab_nm_matmul(x: Array, vals: Array, idx: Array, m_pat: int,
                   b_packed: Array, u: Array, v: Array,
                   *, bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = False) -> Array:
    """N:M variant. vals/idx (N, K/m, n)."""
    m, k = x.shape
    n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert (m % bm == 0 and n % bn == 0 and k % bk == 0
            and bk % 32 == 0 and bk % m_pat == 0)
    bg = bk // m_pat

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm, n_k=grid[2], m_pat=m_pat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx, b_packed, u.reshape(1, n), v.reshape(1, k))
