"""Pallas TPU kernels: grouped-expert variants of the SLaB fused matmuls.

MoE serving hands each expert its own post-dispatch token block — the
capacity-dispatch einsum produces ``(G, E, C, D)`` buffers, flattened
here to x ``(E, M, K)`` with a matching per-expert weight plane stacked
on a leading E axis. One ``pallas_call`` covers a whole expert bucket:
the grid grows a **leading expert dimension** and every BlockSpec gains
a length-1 expert block, so grid step ``(e, i, j[, k])`` streams expert
``e``'s weight tile against expert ``e``'s x tile. K stays the
innermost grid axis for the scratch-accumulator kernels (sequential TPU
grid order ⇒ the fp32 VMEM accumulator carries across K steps exactly
as in the 2-D kernels, re-initialised at ``k == 0`` per (e, i, j)).

The bodies reuse the 2-D kernels' compute helpers verbatim — the only
deltas are the ``ref[0]`` expert-block squeeze on loads, the ``[None]``
on the output store, and ``pl.program_id(3)`` for K. Experts in one
launch share static shape metadata (same variant / rank / ELL K_max pad
— `packed_model.ExpertPackedStack` groups experts into buckets by
realized K_max so ragged experts never pad to the global max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import (accum_binlr_terms, accum_lowrank_proj,
                                  expand_nm_tile, lowrank_epilogue,
                                  unpack_bits_tile)
from repro.kernels.ell import _auto_jc, _gather_accum
from repro.kernels.ell import _Acc

Array = jax.Array


def _espec(block, imap):
    """BlockSpec with a leading length-1 expert block: grid step e owns
    expert plane e; ``imap`` gives the 2-D kernel's index map over the
    remaining grid axes."""
    return pl.BlockSpec((1,) + tuple(block),
                        lambda e, *ij: (e,) + tuple(imap(*ij)))


# --------------------------- ELL family (no K grid) --------------------

def _kernel_ell_g(x_ref, val_ref, idx_ref, o_ref, *, jc: int):
    acc = _gather_accum(x_ref[0], val_ref[0], idx_ref[0], jc)
    o_ref[...] = acc.astype(o_ref.dtype)[None]


def ell_matmul_g(x: Array, vals: Array, idx: Array,
                 *, bm: int = 128, bn: int = 256,
                 jc=None, interpret: bool = False) -> Array:
    """x (E, M, K); vals/idx (E, N, K_max) -> (E, M, N)."""
    e, m, k = x.shape
    _, n, k_max = vals.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (x.shape, vals.shape, bm, bn)
    kernel = functools.partial(_kernel_ell_g,
                               jc=jc or _auto_jc(bm, bn, k_max))
    return pl.pallas_call(
        kernel,
        grid=(e, m // bm, n // bn),
        in_specs=[
            _espec((bm, k), lambda i, j: (i, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
        ],
        out_specs=_espec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx)


def _kernel_ell_lr_g(x_ref, val_ref, idx_ref, u_ref, v_ref, o_ref,
                     *, jc: int):
    x = x_ref[0]
    acc = _gather_accum(x, val_ref[0], idx_ref[0], jc)
    p = jax.lax.dot_general(
        x.astype(jnp.float32), v_ref[0].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y = acc + jax.lax.dot_general(
        p, u_ref[0].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)[None]


def ell_lr_matmul_g(x: Array, vals: Array, idx: Array, u: Array, v: Array,
                    *, bm: int = 128, bn: int = 256,
                    jc=None, interpret: bool = False) -> Array:
    """ELL + rank-r low-rank per expert. u (E, R, N); v (E, R, K)."""
    e, m, k = x.shape
    _, n, k_max = vals.shape
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0
    kernel = functools.partial(_kernel_ell_lr_g,
                               jc=jc or _auto_jc(bm, bn, k_max))
    return pl.pallas_call(
        kernel,
        grid=(e, m // bm, n // bn),
        in_specs=[
            _espec((bm, k), lambda i, j: (i, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
            _espec((rank, bn), lambda i, j: (0, j)),
            _espec((rank, k), lambda i, j: (0, 0)),
        ],
        out_specs=_espec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx, u, v)


def _kernel_slab_ell_g(x_ref, val_ref, idx_ref, bp_ref, u_ref, v_ref,
                       o_ref, *, jc: int, rank: int):
    x = x_ref[0]
    acc = _Acc(_gather_accum(x, val_ref[0], idx_ref[0], jc))
    b = unpack_bits_tile(bp_ref[0], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref[0], v_ref[0], rank)
    o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def slab_ell_matmul_g(x: Array, vals: Array, idx: Array, b_packed: Array,
                      u: Array, v: Array,
                      *, bm: int = 128, bn: int = 256,
                      jc=None, interpret: bool = False) -> Array:
    """Full SLaB with ELL sparse part, per expert. b_packed (E, N, K/32)."""
    e, m, k = x.shape
    _, n, k_max = vals.shape
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    assert b_packed.shape == (e, n, k // 32), (b_packed.shape, e, n, k)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0 and k % 32 == 0
    kernel = functools.partial(_kernel_slab_ell_g,
                               jc=jc or _auto_jc(bm, bn, k_max), rank=rank)
    return pl.pallas_call(
        kernel,
        grid=(e, m // bm, n // bn),
        in_specs=[
            _espec((bm, k), lambda i, j: (i, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
            _espec((bn, k_max), lambda i, j: (j, 0)),
            _espec((bn, k // 32), lambda i, j: (j, 0)),
            _espec((rank, bn), lambda i, j: (0, j)),
            _espec((rank, k), lambda i, j: (0, 0)),
        ],
        out_specs=_espec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx, b_packed, u, v)


# ----------------------- K-gridded family (scratch) --------------------
#
# Grid (E, M/bm, N/bn, K/bk): K innermost so the VMEM accumulator
# carries across K steps of one (e, i, j) tile, exactly as at 2-D.

def _kernel_nm_g(x_ref, val_ref, idx_ref, o_ref, acc,
                 *, n_k: int, m_pat: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0]
    w = expand_nm_tile(val_ref[0], idx_ref[0], m_pat, x.dtype)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def nm_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                *, bm: int = 256, bn: int = 256, bk: int = 512,
                interpret: bool = False) -> Array:
    """x (E, M, K); vals/idx (E, N, K/m, n) -> (E, M, N)."""
    e, m, k = x.shape
    _, n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % m_pat == 0
    bg = bk // m_pat
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm_g, n_k=grid[3], m_pat=m_pat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx)


def _kernel_dense_g(x_ref, ws_ref, bp_ref, u_ref, v_ref, o_ref, acc,
                    *, n_k: int, rank: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0]
    acc[...] += jax.lax.dot_general(
        x, ws_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    b = unpack_bits_tile(bp_ref[0], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref[0], v_ref[0], rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def slab_matmul_g(x: Array, w_s: Array, b_packed: Array, u: Array, v: Array,
                  *, bm: int = 256, bn: int = 256, bk: int = 512,
                  interpret: bool = False) -> Array:
    """Dense-masked SLaB per expert. w_s (E,N,K); b_packed (E,N,K/32)."""
    e, m, k = x.shape
    n = w_s.shape[1]
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_dense_g, n_k=grid[3], rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bk), lambda i, j, kk: (j, kk)),
            _espec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            _espec((rank, bn), lambda i, j, kk: (0, j)),
            _espec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_s, b_packed, u, v)


def _kernel_nm_full_g(x_ref, val_ref, idx_ref, bp_ref, u_ref, v_ref,
                      o_ref, acc, *, n_k: int, m_pat: int, rank: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0]
    w = expand_nm_tile(val_ref[0], idx_ref[0], m_pat, x.dtype)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    b = unpack_bits_tile(bp_ref[0], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref[0], v_ref[0], rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def slab_nm_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                     b_packed: Array, u: Array, v: Array,
                     *, bm: int = 256, bn: int = 256, bk: int = 512,
                     interpret: bool = False) -> Array:
    """N:M SLaB per expert. vals/idx (E, N, K/m, n)."""
    e, m, k = x.shape
    _, n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert (m % bm == 0 and n % bn == 0 and k % bk == 0
            and bk % 32 == 0 and bk % m_pat == 0)
    bg = bk // m_pat
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm_full_g, n_k=grid[3],
                               m_pat=m_pat, rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            _espec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            _espec((rank, bn), lambda i, j, kk: (0, j)),
            _espec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx, b_packed, u, v)


def _kernel_dense_lr_g(x_ref, ws_ref, u_ref, v_ref, o_ref, acc, acc_p,
                       *, n_k: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_p[...] = jnp.zeros_like(acc_p)

    x = x_ref[0]
    acc[...] += jax.lax.dot_general(
        x, ws_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    accum_lowrank_proj(acc_p, x, v_ref[0])

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = lowrank_epilogue(
            acc, acc_p, u_ref[0]).astype(o_ref.dtype)[None]


def slab_lr_matmul_g(x: Array, w_s: Array, u: Array, v: Array,
                     *, bm: int = 256, bn: int = 256, bk: int = 512,
                     interpret: bool = False) -> Array:
    """Dense-masked sparse + rank-r low-rank, no binary, per expert."""
    e, m, k = x.shape
    n = w_s.shape[1]
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_dense_lr_g, n_k=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bk), lambda i, j, kk: (j, kk)),
            _espec((rank, bn), lambda i, j, kk: (0, j)),
            _espec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rank), jnp.float32)],
        interpret=interpret,
    )(x, w_s, u, v)


def _kernel_nm_lr_g(x_ref, val_ref, idx_ref, u_ref, v_ref, o_ref,
                    acc, acc_p, *, n_k: int, m_pat: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        acc_p[...] = jnp.zeros_like(acc_p)

    x = x_ref[0]
    w = expand_nm_tile(val_ref[0], idx_ref[0], m_pat, x.dtype)
    acc[...] += jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    accum_lowrank_proj(acc_p, x, v_ref[0])

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = lowrank_epilogue(
            acc, acc_p, u_ref[0]).astype(o_ref.dtype)[None]


def slab_nm_lr_matmul_g(x: Array, vals: Array, idx: Array, m_pat: int,
                        u: Array, v: Array,
                        *, bm: int = 256, bn: int = 256, bk: int = 512,
                        interpret: bool = False) -> Array:
    """N:M sparse + rank-r low-rank, no binary, per expert."""
    e, m, k = x.shape
    _, n, n_grp, n_keep = vals.shape
    assert n_grp * m_pat == k
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % m_pat == 0
    bg = bk // m_pat
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_nm_lr_g, n_k=grid[3], m_pat=m_pat)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            _espec((bn, bg, n_keep), lambda i, j, kk: (j, kk, 0)),
            _espec((rank, bn), lambda i, j, kk: (0, j)),
            _espec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, rank), jnp.float32)],
        interpret=interpret,
    )(x, vals, idx, u, v)


def _kernel_binlr_g(x_ref, bp_ref, u_ref, v_ref, o_ref, acc,
                    *, n_k: int, rank: int):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[0]
    b = unpack_bits_tile(bp_ref[0], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref[0], v_ref[0], rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc[...].astype(o_ref.dtype)[None]


def binlr_matmul_g(x: Array, b_packed: Array, u: Array, v: Array,
                   *, bm: int = 256, bn: int = 256, bk: int = 512,
                   interpret: bool = False) -> Array:
    """Binary ⊙ rank-r per expert. b_packed (E, N, K/32) uint32."""
    e, m, k = x.shape
    n = b_packed.shape[1]
    assert b_packed.shape[2] * 32 == k
    rank = u.shape[1]
    assert u.shape == (e, rank, n) and v.shape == (e, rank, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0
    grid = (e, m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel_binlr_g, n_k=grid[3], rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            _espec((bm, bk), lambda i, j, kk: (i, kk)),
            _espec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            _espec((rank, bn), lambda i, j, kk: (0, j)),
            _espec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=_espec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((e, m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, b_packed, u, v)
