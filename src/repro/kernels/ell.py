"""Pallas TPU kernels: row-padded ELL unstructured-sparse matmuls.

    y = x @ W_Sᵀ,   W_S streamed as (vals (N, K_max), idx (N, K_max))

ELL storage keeps each output row's non-zeros left-justified and padded
to the realized per-row maximum K_max (uint16 column ids, value 0 at a
zero column for pads), so at b=32 and 50% unstructured sparsity the
streamed bytes are (4+2)/2 = 3 per weight vs 4 dense — the format that
lets unstructured SLaB / HASSLE-free / Wanda layers beat dense bytes
without an N:M constraint.

The compute is a **gather-matmul**: for each (bm, bn) output tile the
kernel gathers x columns through the idx tile and contracts against the
value tile,

    y[m, o] = Σ_j x[m, idx[o, j]] · vals[o, j]

accumulated over K_max in chunks of ``jc`` so the gathered intermediate
stays (bm, bn, jc). Work is nnz-proportional (no dense rebuild, no
wasted zero MACs). K is NOT gridded: each grid step owns a full-K x
block, which the low-rank / binary fusions also consume in one pass:

  ell_matmul      — W_S only.
  ell_lr_matmul   — + rank-r low-rank, no binary: projection p = x @ Vᵀ
                    in one MXU pass, U applied as the epilogue.
  slab_ell_matmul — + binary ⊙ rank-r (full SLaB): the ±1 tile is
                    bit-unpacked once per (bn, K) block and consumed by
                    r rank-1 accumulations (kernels.common helpers).

TPU note: the column gather lowers to Mosaic dynamic-gather along
lanes; on CPU the kernels run in interpret mode (numerics-exact) like
the rest of the kernel family.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import accum_binlr_terms, unpack_bits_tile

Array = jax.Array


def _auto_jc(bm: int, bn: int, k_max: int) -> int:
    """Chunk K_max so the gathered (bm, bn, jc) intermediate stays under
    ~1 MB fp32 — bounds VMEM on TPU and peak working set in interpret."""
    return max(1, min(k_max, (1 << 18) // max(1, bm * bn)))


def _gather_chunk(xf, vals_c, idx_c):
    """One (bm, bn, jc) gather + contract -> (bm, bn) fp32 partial."""
    xg = jnp.take(xf, idx_c.astype(jnp.int32), axis=1)    # (bm, bn, jc)
    return jnp.sum(xg * vals_c.astype(jnp.float32)[None], axis=-1)


def _gather_accum(x, vals, idx, jc: int):
    """(bm, K) x, (bn, K_max) vals/idx -> (bm, bn) fp32 gather-matmul.

    Chunks of jc unroll statically when there are few (smoke/decode
    shapes); at realistic K_max the full chunks run under ONE
    fori_loop so the traced body stays O(1) in K_max, with a single
    static tail for the K_max % jc remainder."""
    bm = x.shape[0]
    bn, k_max = vals.shape
    acc = jnp.zeros((bm, bn), jnp.float32)
    xf = x.astype(jnp.float32)
    n_full, tail0 = k_max // jc, 0
    if n_full > 4:
        def chunk(i, acc):
            j0 = i * jc
            return acc + _gather_chunk(
                xf, jax.lax.dynamic_slice_in_dim(vals, j0, jc, 1),
                jax.lax.dynamic_slice_in_dim(idx, j0, jc, 1))
        acc = jax.lax.fori_loop(0, n_full, chunk, acc)
        tail0 = n_full * jc
    for j0 in range(tail0, k_max, jc):
        acc += _gather_chunk(xf, vals[:, j0:j0 + jc], idx[:, j0:j0 + jc])
    return acc


# ------------------------------ sparse only ----------------------------

def _kernel_ell(x_ref, val_ref, idx_ref, o_ref, *, jc: int):
    acc = _gather_accum(x_ref[...], val_ref[...], idx_ref[...], jc)
    o_ref[...] = acc.astype(o_ref.dtype)


def ell_matmul(x: Array, vals: Array, idx: Array,
               *, bm: int = 128, bn: int = 256,
               jc: Optional[int] = None,
               interpret: bool = False) -> Array:
    """x (M, K); vals (N, K_max); idx (N, K_max) uint16 -> (M, N)."""
    m, k = x.shape
    n, k_max = vals.shape
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0, (x.shape, vals.shape, bm, bn)

    grid = (m // bm, n // bn)
    kernel = functools.partial(_kernel_ell,
                               jc=jc or _auto_jc(bm, bn, k_max))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx)


# -------------------------- + rank-r low-rank --------------------------

def _kernel_ell_lr(x_ref, val_ref, idx_ref, u_ref, v_ref, o_ref,
                   *, jc: int):
    x = x_ref[...]
    acc = _gather_accum(x, val_ref[...], idx_ref[...], jc)
    p = jax.lax.dot_general(                  # (bm, R) = x @ v_blockᵀ
        x.astype(jnp.float32), v_ref[...].astype(jnp.float32),
        (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    y = acc + jax.lax.dot_general(
        p, u_ref[...].astype(jnp.float32),
        (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def ell_lr_matmul(x: Array, vals: Array, idx: Array, u: Array, v: Array,
                  *, bm: int = 128, bn: int = 256,
                  jc: Optional[int] = None,
                  interpret: bool = False) -> Array:
    """ELL sparse + rank-r low-rank, no binary. u (R, N); v (R, K)."""
    m, k = x.shape
    n, k_max = vals.shape
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0

    grid = (m // bm, n // bn)
    kernel = functools.partial(_kernel_ell_lr,
                               jc=jc or _auto_jc(bm, bn, k_max))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
            pl.BlockSpec((rank, bn), lambda i, j: (0, j)),
            pl.BlockSpec((rank, k), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx, u, v)


# ------------------------ + binary ⊙ rank-r ---------------------------

class _Acc:
    """Adapter so accum_binlr_terms's ``acc[...] +=`` protocol works on
    a plain array accumulator (this kernel has no K grid, hence no VMEM
    scratch carry — one body owns the whole reduction)."""

    def __init__(self, a):
        self.a = a

    def __getitem__(self, _):
        return self.a

    def __setitem__(self, _, val):
        self.a = val


def _kernel_slab_ell(x_ref, val_ref, idx_ref, bp_ref, u_ref, v_ref,
                     o_ref, *, jc: int, rank: int):
    x = x_ref[...]
    acc = _Acc(_gather_accum(x, val_ref[...], idx_ref[...], jc))
    b = unpack_bits_tile(bp_ref[...], x.dtype)
    accum_binlr_terms(acc, x, b, u_ref, v_ref, rank)
    o_ref[...] = acc[...].astype(o_ref.dtype)


def slab_ell_matmul(x: Array, vals: Array, idx: Array, b_packed: Array,
                    u: Array, v: Array,
                    *, bm: int = 128, bn: int = 256,
                    jc: Optional[int] = None,
                    interpret: bool = False) -> Array:
    """Full SLaB with ELL sparse part: y = x @ W_Sᵀ + Σ_r ((x⊙v_r) @ Bᵀ)⊙u_r."""
    m, k = x.shape
    n, k_max = vals.shape
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    assert b_packed.shape == (n, k // 32), (b_packed.shape, n, k)
    bm, bn = min(bm, m), min(bn, n)
    assert m % bm == 0 and n % bn == 0 and k % 32 == 0

    grid = (m // bm, n // bn)
    kernel = functools.partial(_kernel_slab_ell,
                               jc=jc or _auto_jc(bm, bn, k_max),
                               rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k_max), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, k // 32), lambda i, j: (j, 0)),
            pl.BlockSpec((rank, bn), lambda i, j: (0, j)),
            pl.BlockSpec((rank, k), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=interpret,
    )(x, vals, idx, b_packed, u, v)
