"""Pallas TPU kernel: bit-packed binary ⊙ rank-1 matmul.

    y (M, N) = ((x ⊙ v) @ Bᵀ) ⊙ u,   B ∈ {±1} packed 32/uint32 word

HBM traffic for the B operand is 1/16th of bf16 — this is the term that
makes SLaB pay on a memory-bound TPU decode (DESIGN.md §3). Grid is
(M/bm, N/bn, K/bk); each step streams an (bn, bk/32) uint32 tile,
expands to ±1 in VMEM, and feeds the MXU. fp32 accumulation in VMEM
scratch; ``u`` is applied once on the last K step.

Block shapes: bm/bn/bk multiples of (8,128) tiles; bk multiple of 32·128
keeps the packed tile lane-aligned (bk/32 lanes of uint32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import unpack_bits_tile

Array = jax.Array


def _kernel(x_ref, bp_ref, u_ref, v_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xv = x_ref[...] * v_ref[...]                       # (bm, bk) ⊙ (1, bk)
    b = unpack_bits_tile(bp_ref[...], xv.dtype)        # (bn, bk) ±1
    acc_ref[...] += jax.lax.dot_general(
        xv, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * u_ref[...].astype(jnp.float32)
                      ).astype(o_ref.dtype)


def binlr_matmul(x: Array, b_packed: Array, u: Array, v: Array,
                 *, bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = False) -> Array:
    """x (M, K); b_packed (N, K/32) uint32; u (N,); v (K,) -> (M, N)."""
    m, k = x.shape
    n = b_packed.shape[0]
    assert b_packed.shape[1] * 32 == k, (b_packed.shape, k)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((1, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, b_packed, u.reshape(1, n), v.reshape(1, k))
