"""Pallas TPU kernel: bit-packed binary ⊙ rank-r matmul.

    y (M, N) = Σ_r ((x ⊙ v_r) @ Bᵀ) ⊙ u_r,   B ∈ {±1} packed 32/uint32

HBM traffic for the B operand is 1/16th of bf16 — this is the term that
makes SLaB pay on a memory-bound TPU decode (DESIGN.md §3). The rank-r
generalization uses (U Vᵀ ⊙ B) x = Σ_r u_r ⊙ (B (v_r ⊙ x)): every rank
term reuses the ONE streamed/expanded B tile, so extra ranks cost MXU
passes but no extra HBM bytes beyond the (R·N + R·K) factor vectors.

Grid is (M/bm, N/bn, K/bk); each step streams an (bn, bk/32) uint32
tile, expands to ±1 in VMEM, and feeds the MXU. fp32 accumulation in
VMEM scratch; ``u_r`` is folded into each step's rank term (it is
constant along K, so per-step scaling equals the end-scaling of the old
rank-1 kernel).

Block shapes: bm/bn/bk multiples of (8,128) tiles; bk multiple of 32·128
keeps the packed tile lane-aligned (bk/32 lanes of uint32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import accum_binlr_terms, unpack_bits_tile

Array = jax.Array


def _kernel(x_ref, bp_ref, u_ref, v_ref, o_ref, acc_ref,
            *, n_k: int, rank: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    b = unpack_bits_tile(bp_ref[...], x.dtype)         # (bn, bk) ±1
    accum_binlr_terms(acc_ref, x, b, u_ref, v_ref, rank)

    @pl.when(k == n_k - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def binlr_matmul(x: Array, b_packed: Array, u: Array, v: Array,
                 *, bm: int = 256, bn: int = 256, bk: int = 512,
                 interpret: bool = False) -> Array:
    """x (M, K); b_packed (N, K/32) uint32; u (R, N); v (R, K) -> (M, N)."""
    m, k = x.shape
    n = b_packed.shape[0]
    assert b_packed.shape[1] * 32 == k, (b_packed.shape, k)
    rank = u.shape[0]
    assert u.shape == (rank, n) and v.shape == (rank, k), (u.shape, v.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0 and bk % 32 == 0

    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_kernel, n_k=grid[2], rank=rank)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk // 32), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((rank, bn), lambda i, j, kk: (0, j)),
            pl.BlockSpec((rank, bk), lambda i, j, kk: (0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, b_packed, u, v)
