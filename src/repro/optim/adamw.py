"""AdamW + cosine schedule + global-norm clipping — pure-pytree, sharding
transparent (optimizer state inherits parameter shardings; bf16 moments
are the memory option the 340B config uses — DESIGN.md §5)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    moment_dtype: Any = jnp.float32     # bf16 option for the 340B config


class OptState(NamedTuple):
    mu: Any
    nu: Any
    count: Array


def adamw_init(params: Any, cfg: AdamWConfig = AdamWConfig()) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return OptState(jax.tree.map(zeros, params), jax.tree.map(zeros, params),
                    jnp.zeros((), jnp.int32))


def cosine_schedule(cfg: AdamWConfig, step: Array) -> Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)


def global_norm_clip(grads: Any, clip: float) -> tuple[Any, Array]:
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), gn


def adamw_update(grads: Any, state: OptState, params: Any,
                 cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = global_norm_clip(grads, cfg.clip_norm)
    count = state.count + 1
    lr = cosine_schedule(cfg, count)
    c = count.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** c
    bc2 = 1 - cfg.b2 ** c

    def upd(p, g, m, n):
        m32, n32 = m.astype(jnp.float32), n.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g
        n_new = cfg.b2 * n32 + (1 - cfg.b2) * g * g
        step = (m_new / bc1) / (jnp.sqrt(n_new / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * (step + decay)
        return (p_new.astype(p.dtype), m_new.astype(cfg.moment_dtype),
                n_new.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(new_mu, new_nu, count), metrics
