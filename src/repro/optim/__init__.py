from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, OptState, adamw_init, adamw_update, cosine_schedule,
    global_norm_clip)
from repro.optim.compress import (  # noqa: F401
    int8_compress, int8_decompress, ef_compress_pytree, ef_decompress_pytree)
