"""Int8 error-feedback gradient compression for the DP all-reduce.

Used by the shard_map DDP train-step variant (runtime.ddp): each replica
quantizes its local gradient to int8 with a per-tensor scale, all-reduces
the int8 payload (8x less DP traffic), dequantizes, and folds the
quantization error into the next step's gradient (error feedback keeps
the scheme unbiased over time — standard 1-bit-Adam lineage result).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def int8_compress(g: Array) -> Tuple[Array, Array]:
    """g float -> (int8 payload, f32 scale). Symmetric per-tensor."""
    g32 = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(g32)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress_pytree(grads: Any, error: Any) -> Tuple[Any, Any, Any]:
    """(grads+error) -> (int8 payloads, scales, new error buffers)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = int8_compress(corrected)
        new_e = corrected - int8_decompress(q, s)
        return q, s, new_e

    out = jax.tree.map(one, grads, error)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    e = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, e


def ef_decompress_pytree(q: Any, s: Any) -> Any:
    return jax.tree.map(int8_decompress, q, s)


def init_error_buffers(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
