"""Llama-3.2-3B. [hf:meta-llama/Llama-3.2-1B family; unverified]
28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv=8, d_head=128,
    d_ff=8192, vocab=128256, act="swiglu", rope="rope",
    rope_theta=500_000.0,
)

SMOKE = FULL.with_(
    name="llama3.2-3b-smoke",
    n_layers=2, d_model=96, n_heads=6, n_kv=2, d_head=16,
    d_ff=192, vocab=512, q_chunk=64,
)
