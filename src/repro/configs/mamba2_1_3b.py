"""Mamba2-1.3B. [arXiv:2405.21060; unverified]
48L d_model=2048, attention-free SSD, ssm_state=128, vocab=50280.
d_inner = 2*2048 = 4096, headdim 64 -> 64 SSD heads."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=1, n_kv=1, d_head=1,
    d_ff=0, vocab=50280, act="swiglu", rope="none",
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_chunk=256,
)

SMOKE = FULL.with_(
    name="mamba2-smoke",
    n_layers=2, d_model=64, vocab=256,
    ssm_state=16, ssm_headdim=16, ssm_chunk=32,
)
