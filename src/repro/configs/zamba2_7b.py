"""Zamba2-7B. [arXiv:2411.15242; unverified]
81L Mamba2 backbone (d_model=3584, ssm_state=64, headdim 64 ->
112 SSD heads) + ONE shared transformer block (32H MHA kv=32,
d_ff=14336) invoked every 6 layers (weight sharing), vocab=32000."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv=32, d_head=112,
    d_ff=14336, vocab=32000, act="swiglu", rope="rope",
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    ssm_chunk=256, attn_every=6,
)

SMOKE = FULL.with_(
    name="zamba2-smoke",
    n_layers=7, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=256, ssm_state=16, ssm_headdim=16,
    ssm_chunk=32, attn_every=3, q_chunk=64,
)
