"""Llama-2-7B — the paper's own evaluation model geometry (Table I).
Used by the benchmark harness (scaled-down trained variants); not an
assigned dry-run cell."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=32, d_head=128,
    d_ff=11008, vocab=32000, act="swiglu", rope="rope",
)

SMOKE = FULL.with_(
    name="llama2-7b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv=4, d_head=32,
    d_ff=344, vocab=512, q_chunk=64,
)
