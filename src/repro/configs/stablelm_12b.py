"""StableLM-2-12B. [hf:stabilityai/stablelm-2-1_6b family; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_head=160,
    d_ff=13824, vocab=100352, act="swiglu", rope="rope",
)

SMOKE = FULL.with_(
    name="stablelm-12b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32,
    d_ff=256, vocab=512, q_chunk=64,
)
