"""Qwen2-VL-2B. [arXiv:2409.12191; hf]
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE.
Vision frontend (dynamic-resolution ViT) is a STUB — prefill consumes
precomputed patch/text embeddings plus (t, h, w) M-RoPE position ids;
decode consumes text token ids. Tied embeddings (2B-class config)."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-2b", family="vlm",
    n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_head=128,
    d_ff=8960, vocab=151936, act="swiglu", rope="mrope",
    mrope_sections=(16, 24, 24), input_mode="embeds",
    tie_embeddings=True,
)

SMOKE = FULL.with_(
    name="qwen2-vl-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
    d_ff=128, vocab=256, mrope_sections=(2, 3, 3), q_chunk=64,
)
