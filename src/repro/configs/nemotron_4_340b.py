"""Nemotron-4-340B. [arXiv:2402.16819; unverified]
96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000,
squared-ReLU MLP (non-gated)."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8, d_head=192,
    d_ff=73728, vocab=256000, act="relu2", rope="rope",
)

SMOKE = FULL.with_(
    name="nemotron-4-340b-smoke",
    n_layers=2, d_model=128, n_heads=8, n_kv=2, d_head=16,
    d_ff=512, vocab=512, q_chunk=64,
)
