"""HuBERT-XLarge. [arXiv:2106.07447; unverified]
48L d_model=1280 16H (kv=16, i.e. MHA) d_ff=5120 vocab=504.
Encoder-only (non-causal); the conv waveform frontend is a STUB —
input_specs provides precomputed frame embeddings (B, S, 1280).
vocab=504 is the k-means unit inventory (masked-unit prediction)."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv=16, d_head=80,
    d_ff=5120, vocab=504, act="gelu", rope="none",
    causal=False, input_mode="embeds",
)

SMOKE = FULL.with_(
    name="hubert-xlarge-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=128, vocab=64, q_chunk=64,
)
