"""Config registry: the 10 assigned architectures (+ the paper's own
Llama-2-7B geometry), each with FULL (assignment-exact) and SMOKE
(reduced, CPU-runnable) variants, and the assigned input-shape sets.

Shape semantics (assignment):
  train_4k     seq 4096,  global_batch 256  -> lowers train_step
  prefill_32k  seq 32768, global_batch 32   -> lowers prefill
  decode_32k   seq 32768 (cache), batch 128 -> lowers serve_step
  long_500k    seq 524288 (cache), batch 1  -> serve_step, SSM/hybrid only

Skips (recorded in DESIGN.md):
  - long_500k needs sub-quadratic attention -> only mamba2 / zamba2.
  - hubert is encoder-only -> no decode shapes.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.models.common import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

ARCH_IDS: List[str] = [
    "stablelm_12b",
    "mistral_nemo_12b",
    "llama3_2_3b",
    "nemotron_4_340b",
    "hubert_xlarge",
    "phi3_5_moe",
    "deepseek_moe_16b",
    "qwen2_vl_2b",
    "mamba2_1_3b",
    "zamba2_7b",
]

# the paper's own evaluation geometry (benchmarks only, not a dry-run cell)
EXTRA_IDS = ["llama2_7b"]


def normalize(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(arch_id)}")
    return mod.SMOKE if smoke else mod.FULL


def shapes_for(cfg: ArchConfig) -> List[ShapeSpec]:
    """Assignment applicability: which shape cells this arch runs."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
    if cfg.family == "audio":       # encoder-only: no decode
        return out
    out.append(SHAPES["decode_32k"])
    if cfg.family in ("ssm", "hybrid"):   # sub-quadratic: long-context cell
        out.append(SHAPES["long_500k"])
    return out


def all_cells() -> List[tuple]:
    """Every (arch_id, shape) dry-run cell."""
    cells = []
    for a in ARCH_IDS:
        cfg = get(a)
        for s in shapes_for(cfg):
            cells.append((a, s.name))
    return cells
