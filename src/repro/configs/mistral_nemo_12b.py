"""Mistral-Nemo-12B. [hf:mistralai/Mistral-Nemo-Base-2407; hf]
40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072, 128k ctx
(head_dim 128 per the HF config; rope theta 1e6 for long context)."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_head=128,
    d_ff=14336, vocab=131072, act="swiglu", rope="rope",
    rope_theta=1_000_000.0,
)

SMOKE = FULL.with_(
    name="mistral-nemo-12b-smoke",
    n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32,
    d_ff=256, vocab=512, q_chunk=64,
)
