"""Phi-3.5-MoE (42B total / 6.6B active). [hf:microsoft/Phi-3.5-MoE-instruct; hf]
32L d_model=4096 32H (GQA kv=8) d_ff=6400/expert, 16 experts top-2,
vocab=32064."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=6400, vocab=32064, act="swiglu", rope="rope",
    n_experts=16, top_k=2,
)

SMOKE = FULL.with_(
    name="phi3.5-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
    d_ff=128, vocab=256, n_experts=4, top_k=2, moe_group=64, q_chunk=64,
)
