"""DeepSeek-MoE-16B. [arXiv:2401.06066; hf]
28L d_model=2048 16H (GQA kv=16, i.e. MHA) d_ff=1408/expert,
64 routed experts top-6 + 2 shared experts (fine-grained), vocab=102400.
Shared experts modeled as one always-on gated MLP of width 2*1408."""
from repro.models.common import ArchConfig

FULL = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=102400, act="swiglu", rope="rope",
    n_experts=64, top_k=6, shared_ff=2816,
)

SMOKE = FULL.with_(
    name="deepseek-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
    d_ff=96, vocab=256, n_experts=8, top_k=3, shared_ff=192,
    moe_group=64, q_chunk=64,
)
