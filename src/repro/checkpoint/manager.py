"""Fault-tolerant checkpointing: atomic commits, async writer, cross-mesh
(elastic) restore.

Layout: <dir>/step_<n>/  with one .npy per pytree leaf (path-encoded
names) + manifest.json. Writes go to a temp dir and are os.rename'd into
place — a crash mid-write never corrupts the latest commit (rename is
atomic on POSIX). An optional writer thread makes saves non-blocking for
the train loop (the arrays are device_get'd synchronously — cheap next to
a step — then serialized off-thread).

Elastic restore: leaves are loaded as host numpy and re-placed with
``jax.device_put(x, sharding)`` for whatever mesh the *new* job built —
restoring a 512-chip checkpoint onto 256 chips (or a different layout)
is just a different sharding argument. This is the cross-mesh resharding
path the elastic-scaling story needs.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

SEP = "__"


def _flatten(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def _to_native(arr: np.ndarray):
    """numpy can't serialize ml_dtypes (bfloat16, fp8). View as raw bytes
    and record the true dtype in the manifest."""
    if arr.dtype.kind == "V" or arr.dtype.name not in np.sctypeDict:
        return arr.view(np.uint8).reshape(arr.shape + (arr.dtype.itemsize,)), \
            arr.dtype.name
    return arr, arr.dtype.name


def _from_native(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in np.sctypeDict and arr.dtype.name == dtype_name:
        return arr
    import ml_dtypes  # ships with jax
    dt = np.dtype(getattr(ml_dtypes, dtype_name, dtype_name))
    return arr.reshape(arr.shape[:-1] + (-1,)).view(dt).reshape(
        arr.shape[:-1])


def save_pytree(tree: Any, directory: str) -> None:
    """Atomic: write to <dir>.tmp then rename."""
    tmp = directory + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    manifest = {}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        native, dtype_name = _to_native(arr)
        np.save(os.path.join(tmp, key + ".npy"), native)
        manifest[key] = {"shape": list(arr.shape), "dtype": dtype_name}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(directory):
        shutil.rmtree(directory)
    os.rename(tmp, directory)


def load_pytree(template: Any, directory: str,
                shardings: Optional[Any] = None) -> Any:
    """Rebuild ``template``'s structure from disk. ``shardings`` (same
    structure, jax.sharding.Sharding leaves) re-places each leaf onto the
    current mesh — the elastic/cross-mesh restore path."""
    with open(os.path.join(directory, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t = _flatten(template)
    flat_s = _flatten(shardings) if shardings is not None else None
    loaded = {}
    for key in flat_t:
        arr = np.load(os.path.join(directory, key + ".npy"))
        arr = _from_native(arr, manifest[key]["dtype"])
        if flat_s is not None:
            loaded[key] = jax.device_put(arr, flat_s[key])
        else:
            loaded[key] = jax.numpy.asarray(arr)
    leaves_paths = jax.tree_util.tree_flatten_with_path(template)
    keys = [SEP.join(_path_str(p) for p in path)
            for path, _ in leaves_paths[0]]
    return jax.tree_util.tree_unflatten(leaves_paths[1],
                                        [loaded[k] for k in keys])


class CheckpointManager:
    """Keeps the last ``keep`` commits; optional async writer thread."""

    def __init__(self, root: str, keep: int = 3, async_write: bool = True):
        self.root = root
        self.keep = keep
        self.async_write = async_write
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        os.makedirs(root, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.root):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save(self, step: int, tree: Any) -> None:
        self.wait()                      # one in-flight save at a time
        # device_get NOW so the train loop can donate/mutate buffers
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save_pytree(host_tree, self._step_dir(step))
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        if self.async_write:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Any:
        self.wait()
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.root}")
        return load_pytree(template, self._step_dir(step), shardings)

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
