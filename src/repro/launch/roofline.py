"""Roofline analysis over dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all in seconds-per-step:

  compute    = flops_per_device            / PEAK_FLOPS_BF16
  memory     = hbm_bytes_per_device        / HBM_BW
  collective = collective_bytes_per_device / ICI_BW

where flops/bytes come from ``compiled.cost_analysis()`` (per-device,
while-bodies scaled by trip counts — see hlo_stats) and collective bytes
from the HLO text parse. The dominant term is the bottleneck; the
utilization column is compute/max(all) — the fraction of peak the chip
would sustain if the model were perfectly overlapped, i.e. the roofline
fraction reported in §Perf.

MODEL_FLOPS sanity column: 6·N·D for train (N params — active params for
MoE — D tokens), 2·N·D for forward-only cells, per device; the ratio
model/HLO catches remat waste and redundant compute (useful < 1 means
the compiled program does more dot-flops than the model needs: remat
recompute, replicated attention under dropped TP rules, MoE dispatch).

NOTE: XLA's cost_analysis counts while bodies once, so flops/bytes are
re-derived from the HLO text with per-computation trip-count multipliers
(launch.hlo_stats — validated to match analytic flop counts exactly on
scanned matmul programs). The raw cost_analysis values remain in the
artifacts (--raw to view).
"""
from __future__ import annotations

import argparse
import glob
import json
import math
import os
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    flops_dev: float          # per device, loop-scaled
    bytes_dev: float          # per device, loop-scaled
    coll_bytes_dev: float     # per device (operand-size convention)
    coll_wire_dev: float
    model_flops_dev: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    roofline_frac: float = 0.0
    useful_ratio: float = 0.0

    def finish(self):
        self.compute_s = self.flops_dev / PEAK_FLOPS_BF16
        self.memory_s = self.bytes_dev / HBM_BW
        self.collective_s = self.coll_bytes_dev / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        tmax = max(terms.values())
        self.roofline_frac = (self.compute_s / tmax) if tmax > 0 else 0.0
        self.useful_ratio = (self.model_flops_dev / self.flops_dev
                             if self.flops_dev else 0.0)
        return self


def model_flops_per_device(arch_id: str, shape_name: str, chips: int
                           ) -> float:
    """6·N·D (train) / 2·N·D (fwd) global, divided by chips."""
    from repro import configs
    from repro.models import lm
    cfg = configs.get(arch_id)
    shape = configs.SHAPES[shape_name]
    n_active = lm.active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / chips


def load_rows(dryrun_dir: str, use_hlo: bool = True) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            d = json.load(f)
        if not d.get("ok"):
            continue
        chips = 512 if d["mesh"] == "multi" else 256
        coll = (d.get("collectives") or {}).get("total", {})
        mf = model_flops_per_device(d["arch"], d["shape"], chips)
        # trip-scaled HLO-text numbers (validated against analytic flops);
        # cost_analysis values count while bodies once and are kept in the
        # JSON artifacts for reference only.
        flops = d.get("hlo_flops") or d["flops"]
        bytes_dev = d.get("hlo_bytes") or d["bytes_accessed"]
        if not use_hlo:
            flops, bytes_dev = d["flops"], d["bytes_accessed"]
        rows.append(RooflineRow(
            arch=d["arch"], shape=d["shape"], mesh=d["mesh"],
            kind=d["kind"], chips=chips, flops_dev=flops,
            bytes_dev=bytes_dev,
            coll_bytes_dev=coll.get("operand_bytes", 0.0),
            coll_wire_dev=coll.get("wire_bytes", 0.0),
            model_flops_dev=mf).finish())
    return rows


def fmt_table(rows: List[RooflineRow]) -> str:
    hdr = (f"{'arch':20s} {'shape':12s} {'mesh':6s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'collect_s':>10s} "
           f"{'dominant':>10s} {'roofl%':>7s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:20s} {r.shape:12s} {r.mesh:6s} "
            f"{r.compute_s:10.4f} {r.memory_s:10.4f} {r.collective_s:10.4f} "
            f"{r.dominant:>10s} {100*r.roofline_frac:6.1f}% "
            f"{r.useful_ratio:6.2f}")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--json-out", default=None)
    ap.add_argument("--raw", action="store_true",
                    help="use raw cost_analysis numbers (loop bodies x1)")
    args = ap.parse_args()
    rows = load_rows(args.dir, use_hlo=not args.raw)
    print(fmt_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([r.__dict__ for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
