"""Post-SPMD HLO text analysis: trip-scaled flops / bytes / collectives.

XLA's ``compiled.cost_analysis()`` reports *per-device* flops/bytes and
counts while-loop bodies ONCE (verified empirically on this jax build),
so this module re-derives the roofline inputs from the HLO text itself,
multiplying every instruction by the trip counts of its enclosing while
loops (the layer scan, the microbatch scan, the query-chunk scan ...).

Trip counts come from each while's condition computation: lax.scan
lowers to ``compare(counter, constant(N)), direction=LT`` with a 0-based
counter, so the s32 constant is the trip count. Multipliers propagate
through the call graph: while bodies (x trips), fusion ``calls=``,
``to_apply=``, and conditional branches (x1).

Derived quantities (all per device):
  hlo_flops  — Σ over dot ops: mult · 2 · |result| · |contracted dims|
               (convolutions are negligible in these models: the mamba
               depthwise conv is lowered to shifted multiply-adds).
  hlo_bytes  — Σ over *materialized* ops: mult · (output + operand bytes).
               Fusion bodies are skipped (their intermediates live in
               registers/VMEM); the fusion call site's operands + output
               are what cross HBM. Tuple plumbing/parameters excluded.
  collectives — per-type counts + two byte conventions:
       operand_bytes — printed input-operand sizes (the spec convention);
       wire_bytes    — ring-algorithm per-device traffic:
           all-gather (g-1)/g·out | all-reduce 2·(g-1)/g·out |
           reduce-scatter (g-1)·out | all-to-all (g-1)/g·out |
           collective-permute out
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_WHILE_RE = re.compile(
    r"while\(.*?\), condition=%([\w.\-]+), body=%([\w.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w.\-]+)")
_BRANCH_RE = re.compile(
    r"(?:true_computation|false_computation|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_NAME_RE = re.compile(r"%([\w.\-]+)")
_PARAM_N_RE = re.compile(r"parameter\((\d+)\)")

_SKIP_BYTES_OPS = {"parameter", "tuple", "get-tuple-element", "constant",
                   "bitcast", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "call", "copy-done",
                   "all-gather-done", "all-reduce-done", "broadcast",
                   "iota"}


def _first_shape(result_part: str) -> Tuple[Optional[str], List[int]]:
    m = _SHAPE_RE.search(result_part)
    if not m:
        return None, []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


def _shape_bytes(result_part: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(result_part):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))            # [num_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _operand_names(line: str, opcode: str) -> List[str]:
    """Operand instruction names of a call, tolerant of /*index=N*/
    comments that XLA injects into long operand lists."""
    parts = line.split(f" {opcode}(", 1)
    if len(parts) != 2:
        return []
    inner = parts[1].split(")", 1)[0]
    return _NAME_RE.findall(inner)


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.lines: List[str] = []
        # instr name -> (dtype, dims, opcode)
        self.symbols: Dict[str, Tuple[Optional[str], List[int], str]] = {}
        # param index -> instr name
        self.params: Dict[int, str] = {}

    def index(self):
        for line in self.lines:
            m = _INSTR_RE.match(line)
            if m:
                name, result, opcode = m.group(1), m.group(2), m.group(3)
                dt, dims = _first_shape(result)
                self.symbols[name] = (dt, dims, opcode)
                if opcode == "parameter":
                    pm = _PARAM_N_RE.search(line)
                    if pm:
                        self.params[int(pm.group(1))] = name

    def size_of(self, name: str) -> float:
        sym = self.symbols.get(name)
        if not sym or sym[0] not in _DTYPE_BYTES:
            return 0.0
        n = 1
        for d in sym[1]:
            n *= d
        return n * _DTYPE_BYTES[sym[0]]

    _PASSTHROUGH = {"bitcast", "copy", "convert", "reshape", "transpose",
                    "get-tuple-element"}

    def param_charges(self) -> Dict[int, float]:
        """For fusion bodies: bytes actually READ from each parameter,
        traced through pass-through ops. A parameter reaching only
        (dynamic-)slice ops contributes the slice outputs (carried-stack
        reads one layer per iteration); one reaching only a dynamic-
        update-slice *target* slot aliases in place and contributes 0;
        anything else reads in full."""
        # consumers: value name -> [(opcode, out_bytes, operand_pos, name)]
        consumers: Dict[str, List] = {}
        for line in self.lines:
            m = _INSTR_RE.match(line)
            if not m or m.group(3) == "parameter":
                continue
            opcode, out_b = m.group(3), _shape_bytes(m.group(2))
            for pos, op in enumerate(_operand_names(line, opcode)):
                consumers.setdefault(op, []).append(
                    (opcode, out_b, pos, m.group(1)))

        def charge(vname: str, depth: int = 0) -> Optional[float]:
            """bytes read from value v; None => read in full."""
            if depth > 8:
                return None
            total = 0.0
            for opcode, out_b, pos, cname in consumers.get(vname, []):
                if opcode in self._PASSTHROUGH:
                    sub = charge(cname, depth + 1)
                    if sub is None:
                        return None
                    total += sub
                elif opcode in ("dynamic-slice", "slice"):
                    total += out_b
                elif opcode == "dynamic-update-slice" and pos == 0:
                    pass                     # in-place target
                else:
                    return None
            return total

        charges = {}
        for i, pname in self.params.items():
            c = charge(pname)
            charges[i] = self.size_of(pname) if c is None else c
        return charges


def _split_computations(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry = ""
    for line in text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and not line.startswith(" "):
            current = Computation(m.group(2))
            comps[current.name] = current
            if m.group(1):
                entry = current.name
            continue
        if line.startswith("}"):
            current = None
            continue
        if current is not None:
            current.lines.append(line)
    for c in comps.values():
        c.index()
    return comps, entry


def _trip_count(comp: Optional[Computation]) -> Tuple[int, bool]:
    if comp is None:
        return 1, False
    for line in comp.lines:
        m = _CONST_RE.search(line)
        if m:
            return int(m.group(1)), True
    return 1, False


def _multipliers(comps: Dict[str, Computation], entry: str
                 ) -> Tuple[Dict[str, float], bool]:
    edges: Dict[str, List[Tuple[str, float]]] = {}
    all_parsed = True
    for name, comp in comps.items():
        out: List[Tuple[str, float]] = []
        for line in comp.lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                trips, ok = _trip_count(comps.get(cond))
                all_parsed = all_parsed and ok
                out.append((body, float(trips)))
                continue
            cm = _CALLS_RE.search(line)
            if cm:
                out.append((cm.group(1), 1.0))
            bm = _BRANCH_RE.search(line)
            if bm:
                for b in bm.group(1).replace("%", "").split(","):
                    out.append((b.strip(), 1.0))
        edges[name] = out

    mult: Dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0):
        if name not in comps or depth > 64:
            return
        if mult.get(name, 0.0) >= m:
            return
        mult[name] = m
        for child, f in edges.get(name, []):
            visit(child, m * f, depth + 1)

    if entry:
        visit(entry, 1.0)
    for name in comps:
        mult.setdefault(name, 1.0)
    return mult, all_parsed


def analyze(text: str) -> Dict:
    """One-pass full analysis: flops, bytes, collectives, op census."""
    comps, entry = _split_computations(text)
    mult, trips_parsed = _multipliers(comps, entry)

    # computations invoked as fusion/reduction bodies: intermediates live
    # in registers — only the call site's operands/output touch HBM.
    fused_bodies = set()
    for comp in comps.values():
        for line in comp.lines:
            cm = _CALLS_RE.search(line)
            if cm:
                fused_bodies.add(cm.group(1))

    flops = 0.0
    bytes_rw = 0.0
    per_type: Dict[str, Dict[str, float]] = {
        op: {"count": 0.0, "operand_bytes": 0.0, "wire_bytes": 0.0}
        for op in COLLECTIVE_OPS}
    op_census: Dict[str, float] = {}

    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        count_bytes = name not in fused_bodies
        for line in comp.lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            iname, result, opcode = im.group(1), im.group(2), im.group(3)
            op_census[opcode] = op_census.get(opcode, 0.0) + m

            # ---- bytes: output write + operand reads, at call sites only
            if count_bytes and opcode not in _SKIP_BYTES_OPS:
                out_b = _shape_bytes(result)
                op_names = _operand_names(line, opcode)
                op_bytes = [comp.size_of(o) for o in op_names]

                # fusion bodies tell us how much of each operand is
                # actually read (dynamic-slice of a carried stack reads
                # one slice; a dynamic-update-slice target aliases in
                # place and reads nothing)
                if opcode == "fusion":
                    cm = _CALLS_RE.search(line)
                    body = comps.get(cm.group(1)) if cm else None
                    if body is not None:
                        charges = body.param_charges()
                        op_bytes = [
                            min(op_bytes[i], charges.get(i, op_bytes[i]))
                            for i in range(len(op_bytes))]
                        # in-place update: output aliases the big operand
                        has_dus = any(s[2] == "dynamic-update-slice"
                                      for s in body.symbols.values())
                        if has_dus and out_b >= max(op_bytes + [1.0]):
                            out_b = sum(op_bytes)      # writes ≈ reads
                elif opcode == "dynamic-update-slice" and op_bytes:
                    small = sum(op_bytes) - max(op_bytes)
                    op_bytes = [small]
                    out_b = small
                elif opcode in ("dynamic-slice", "slice") and op_bytes:
                    op_bytes = [out_b]
                bytes_rw += m * (out_b + sum(op_bytes))

            # ---- dot flops
            if opcode == "dot":
                dt, rdims = _first_shape(result)
                # operand names via the comment/type-tolerant helper:
                # newer XLA prints typed operands ("dot(f32[..] %a, ..)"),
                # which a bare "dot(%a, %b)" pattern misses — dropping the
                # contracted-dim factor from every while-body matmul.
                dot_ops = _operand_names(line, opcode)
                cm = _LHS_CONTRACT_RE.search(line)
                contracted = 1
                if dot_ops and cm and cm.group(1):
                    lhs = comp.symbols.get(dot_ops[0])
                    if lhs:
                        for d in cm.group(1).split(","):
                            di = int(d)
                            if di < len(lhs[1]):
                                contracted *= lhs[1][di]
                rsize = 1
                for d in rdims:
                    rsize *= d
                flops += m * 2.0 * rsize * contracted

            # ---- collectives
            base = opcode[:-6] if opcode.endswith("-start") else opcode
            if base in COLLECTIVE_OPS and not opcode.endswith("-done"):
                out_b = _shape_bytes(result)
                g = _group_size(line)
                if base == "all-gather":
                    operand = out_b / max(g, 1)
                    wire = out_b * (g - 1) / max(g, 1)
                elif base == "all-reduce":
                    operand = out_b
                    wire = 2 * out_b * (g - 1) / max(g, 1)
                elif base == "reduce-scatter":
                    operand = out_b * g
                    wire = out_b * (g - 1)
                elif base == "all-to-all":
                    operand = out_b
                    wire = out_b * (g - 1) / max(g, 1)
                else:
                    operand = out_b
                    wire = out_b
                d = per_type[base]
                d["count"] += m
                d["operand_bytes"] += m * operand
                d["wire_bytes"] += m * wire

    totals = {
        "count": sum(d["count"] for d in per_type.values()),
        "operand_bytes": sum(d["operand_bytes"] for d in per_type.values()),
        "wire_bytes": sum(d["wire_bytes"] for d in per_type.values()),
    }
    top_ops = dict(sorted(op_census.items(), key=lambda kv: -kv[1])[:20])
    return {
        "hlo_flops": flops,
        "hlo_bytes": bytes_rw,
        "collectives": {"per_type": per_type, "total": totals,
                        "trip_counts_parsed": trips_parsed},
        "op_census_top": top_ops,
    }


def collective_stats(text: str) -> Dict:
    return analyze(text)["collectives"]


def loop_multipliers(text: str) -> Dict[str, float]:
    comps, entry = _split_computations(text)
    mult, _ = _multipliers(comps, entry)
    return mult


def scaled_instruction_count(text: str, opcode: str) -> float:
    """Trip-count-scaled occurrences of an opcode — used by the perf loop
    to spot remat recompute and redundant collectives."""
    comps, entry = _split_computations(text)
    mult, _ = _multipliers(comps, entry)
    total = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 1.0)
        for sym, (dt, dims, op) in comp.symbols.items():
            if op == opcode:
                total += m
    return total
