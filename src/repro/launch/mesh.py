"""Production meshes. TPU v5e pod = 16x16 = 256 chips; the multi-pod
mesh adds a leading DCN-connected "pod" axis (2 pods = 512 chips).

Functions, not module constants — importing this module never touches
jax device state (device count is locked at first jax init, and only
the dry-run entrypoint forces 512 host devices)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small meshes for unit tests (requires enough local devices)."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
