import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count at init.
"""Multi-pod dry-run entrypoint.

Lowers + compiles every (architecture x input-shape) cell against the
single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, printing
memory_analysis / cost_analysis and writing one JSON artifact per cell
(consumed by launch.roofline and EXPERIMENTS.md §Dry-run).

Usage:
  python -m repro.launch.dryrun --arch stablelm_12b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import sys

import jax


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--remat", type=str, default="nothing")
    ap.add_argument("--mb-per-device", type=int, default=1)
    ap.add_argument("--no-hlo-stats", action="store_true")
    ap.add_argument("--serve-replicate-embed", action="store_true",
                    help="§Perf variant: replicate FSDP dims at serve")
    args = ap.parse_args()

    assert jax.device_count() == 512, (
        f"dry-run needs 512 placeholder devices, got {jax.device_count()}")

    from repro import configs
    from repro.launch import cell as cell_lib
    from repro.launch.mesh import make_production_mesh

    if args.all:
        cells = configs.all_cells()
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    n_fail = 0
    for arch_id, shape_name in cells:
        for mesh_name, mesh in meshes:
            res = cell_lib.run_cell(
                arch_id, shape_name, mesh, mesh_name,
                microbatch_per_device=args.mb_per_device,
                remat=args.remat,
                with_hlo_stats=not args.no_hlo_stats,
                serve_replicate_embed=args.serve_replicate_embed)
            path = cell_lib.save_result(res, args.out)
            n_dev = 512 if mesh_name == "multi" else 256
            if res.ok:
                coll = (res.collectives or {}).get("total", {})
                print(f"OK   {arch_id:18s} {shape_name:12s} {mesh_name:6s} "
                      f"lower={res.lower_s:6.1f}s compile={res.compile_s:6.1f}s "
                      f"flops/dev={res.flops:.3e} bytes/dev={res.bytes_accessed:.3e} "
                      f"peakmem/dev={res.peak_memory_per_device/2**30:.2f}GiB "
                      f"collbytes/dev={coll.get('operand_bytes', 0):.3e} "
                      f"-> {path}", flush=True)
            else:
                n_fail += 1
                print(f"FAIL {arch_id:18s} {shape_name:12s} {mesh_name:6s} "
                      f"{res.error}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
