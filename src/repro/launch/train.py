"""Training driver: config-driven, checkpointed, fault-tolerant.

Runs REAL training at whatever scale the local device set allows (the
CPU container trains the reduced configs; on a pod the same entrypoint
takes the full ones):

  python -m repro.launch.train --arch llama2_7b --smoke --steps 200 \
      --batch 16 --seq 256 --ckpt-dir /tmp/run1

Features exercised end-to-end: synthetic data pipeline keyed by (seed,
step, host), microbatched grad accumulation, remat policy, AdamW +
cosine, atomic async checkpoints, watchdog supervision with restore-and-
replay, elastic restore onto a different mesh (--restore-from).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticCorpus
from repro.models import lm
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime import specs as specs_lib
from repro.runtime.elastic import elastic_restore
from repro.runtime.fault import FaultConfig, Supervisor
from repro.runtime.meshctx import use_mesh
from repro.runtime.sharding import Planner
from repro.runtime.step import make_train_fn


def build_mesh(data: int, model: int) -> Mesh:
    return jax.make_mesh((data, model), ("data", "model"))


def train(arch: str, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: Optional[str], data_par: int = 1, model_par: int = 1,
          microbatches: int = 1, remat: str = "none",
          lr: float = 3e-4, seed: int = 0, log_every: int = 10,
          ckpt_every: int = 50, restore: bool = False,
          inject_failure_at: Optional[int] = None):
    cfg = configs.get(arch, smoke=smoke)
    mesh = build_mesh(data_par, model_par)
    planner = Planner(mesh, cfg)
    acfg = AdamWConfig(lr=lr, total_steps=max(steps, 2),
                       warmup_steps=max(steps // 20, 1))

    params, axes = lm.init(cfg, jax.random.PRNGKey(seed))
    p_sh = planner.tree_shardings(axes, params)
    params = jax.device_put(params, p_sh)
    opt = adamw_init(params, acfg)

    corpus = SyntheticCorpus(cfg.vocab, seed=seed)
    with use_mesh(mesh):
        fn = make_train_fn(cfg, acfg, planner, microbatches=microbatches,
                           remat=remat)
        step_jit = jax.jit(fn, donate_argnums=(0, 1))

    mgr = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
    start = 0
    state = {"params": params, "opt": opt}
    if restore and mgr and mgr.latest_step() is not None:
        state = elastic_restore(mgr, cfg, acfg, mesh)
        start = mgr.latest_step()
        print(f"restored step {start}")

    def make_batch(step: int):
        b = corpus.batch(step, batch, seq)
        if cfg.input_mode == "embeds":
            rng = np.random.default_rng(step)
            b["inputs"] = rng.standard_normal(
                (batch, seq, cfg.d_model), dtype=np.float32)
        return {k: jnp.asarray(v) for k, v in b.items()}

    losses = []

    def step_fn(state, step):
        if inject_failure_at is not None and step == inject_failure_at:
            # one-shot injection: only fail the first time we reach it
            state.setdefault("_failed", False)
            if not state["_failed"]:
                state["_failed"] = True
                raise RuntimeError("injected")
        p, o, m = step_jit(state["params"], state["opt"], make_batch(step))
        new = {"params": p, "opt": o}
        if "_failed" in state:
            new["_failed"] = state["_failed"]
        return new, m

    def restore_fn(at_step):
        st = elastic_restore(mgr, cfg, acfg, mesh, step=at_step)
        st["_failed"] = True
        return st

    sup = Supervisor(mgr, FaultConfig(ckpt_every=ckpt_every)) if mgr else None

    def on_metrics(step, m):
        losses.append(float(m["loss"]))
        if step % log_every == 0:
            print(f"step {step:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e}", flush=True)

    t0 = time.monotonic()
    if sup:
        # supervisor checkpoints {"params","opt"} (drop bookkeeping keys)
        class MgrView:
            def __init__(self, mgr):
                self.m = mgr
            def save(self, step, tree):
                self.m.save(step, {"params": tree["params"],
                                   "opt": tree["opt"]})
            def __getattr__(self, k):
                return getattr(self.m, k)
        sup.mgr = MgrView(mgr)
        state = sup.run(state, start, steps, step_fn, restore_fn,
                        on_metrics)
        print(f"restarts={sup.stats.restarts} "
              f"stragglers={sup.stats.stragglers}")
    else:
        for s in range(start, steps):
            state, m = step_fn(state, s)
            on_metrics(s, m)
    dt = time.monotonic() - t0
    print(f"trained {steps - start} steps in {dt:.1f}s "
          f"({(steps - start) / max(dt, 1e-9):.2f} steps/s); "
          f"final loss {losses[-1]:.4f}")
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--data-par", type=int, default=1)
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    train(args.arch, args.smoke, args.steps, args.batch, args.seq,
          args.ckpt_dir, args.data_par, args.model_par, args.microbatches,
          args.remat, args.lr, args.seed, ckpt_every=args.ckpt_every,
          restore=args.restore)


if __name__ == "__main__":
    main()
