"""One dry-run cell: lower + compile a (arch x shape x mesh) program and
extract its analysis artifacts. Importable (tests run it on tiny meshes);
``launch.dryrun`` is the 512-device entrypoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_stats
from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime import specs as specs_lib
from repro.runtime.meshctx import use_mesh
from repro.runtime.sharding import Planner
from repro.runtime.step import make_serve_fn, make_train_fn, make_prefill_fn

# bf16 optimizer moments for the 340B config (memory budget, DESIGN §5)
BF16_MOMENT_ARCHS = {"nemotron_4_340b"}


def adamw_config_for(arch_id: str) -> AdamWConfig:
    if configs.normalize(arch_id) in BF16_MOMENT_ARCHS:
        return AdamWConfig(moment_dtype=jnp.bfloat16)
    return AdamWConfig()


def pick_microbatches(shape: configs.ShapeSpec, planner: Planner,
                      per_device: int = 1) -> int:
    """Gradient-accumulation depth: one (or ``per_device``) sequence(s)
    per device per microbatch — the live-activation budget at 340B."""
    dp = 1
    for a in planner.batch_axes():
        dp *= planner.mesh.shape[a]
    mb = max(1, shape.global_batch // (dp * per_device))
    while shape.global_batch % mb:
        mb -= 1
    return mb


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    kind: str
    ok: bool
    lower_s: float = 0.0
    compile_s: float = 0.0
    flops: float = 0.0
    bytes_accessed: float = 0.0
    hlo_flops: float = 0.0          # trip-scaled, from HLO text
    hlo_bytes: float = 0.0          # trip-scaled read+write estimate
    peak_memory_per_device: float = 0.0
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    temp_bytes: float = 0.0
    collectives: Optional[Dict[str, Any]] = None
    microbatches: int = 1
    error: Optional[str] = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def lower_cell(arch_id: str, shape_name: str, mesh: Mesh,
               microbatch_per_device: int = 1, remat: str = "nothing",
               cfg_override: Optional[ArchConfig] = None,
               shape_override: Optional[configs.ShapeSpec] = None,
               serve_replicate_embed: bool = False,
               kv_quant: bool = False,
               grad_dtype=jnp.float32):
    """Returns (lowered, meta) for the cell's program."""
    cfg = cfg_override if cfg_override is not None else configs.get(arch_id)
    if kv_quant:
        cfg = cfg.with_(kv_quant=True)
    shape = shape_override or configs.SHAPES[shape_name]
    planner = Planner(mesh, cfg)
    if serve_replicate_embed:                       # §Perf variant
        rules = dict(planner.rules)
        rules["embed"] = []
        planner = Planner(mesh, cfg, rules=rules)
    acfg = adamw_config_for(arch_id)
    meta: Dict[str, Any] = {"kind": shape.kind}

    with use_mesh(mesh):
        if shape.kind == "train":
            mb = pick_microbatches(shape, planner, microbatch_per_device)
            meta["microbatches"] = mb
            fn = make_train_fn(cfg, acfg, planner, microbatches=mb,
                               remat=remat, grad_dtype=grad_dtype)
            params, _ = specs_lib.abstract_params(cfg, planner)
            opt, _ = specs_lib.abstract_opt_state(cfg, planner, acfg)
            batch = specs_lib.batch_specs(cfg, shape, planner)
            lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
                params, opt, batch)
        elif shape.kind == "prefill":
            fn = make_prefill_fn(cfg, planner)
            params, _ = specs_lib.abstract_params(cfg, planner)
            batch = specs_lib.batch_specs(cfg, shape, planner)
            args = (params, batch["inputs"])
            if "positions" in batch:
                args = args + (batch["positions"],)
            lowered = jax.jit(fn).lower(*args)
        else:  # decode
            fn = make_serve_fn(cfg, planner)
            params, _ = specs_lib.abstract_params(cfg, planner)
            cache, token, pos = specs_lib.decode_specs(cfg, shape, planner)
            lowered = jax.jit(fn, donate_argnums=(1,)).lower(
                params, cache, token, pos)
    return lowered, meta


def run_cell(arch_id: str, shape_name: str, mesh: Mesh, mesh_name: str,
             microbatch_per_device: int = 1, remat: str = "nothing",
             with_hlo_stats: bool = True,
             cfg_override: Optional[ArchConfig] = None,
             shape_override: Optional[configs.ShapeSpec] = None,
             serve_replicate_embed: bool = False,
             kv_quant: bool = False,
             grad_dtype=jnp.float32) -> CellResult:
    shape = shape_override or configs.SHAPES[shape_name]
    res = CellResult(arch=arch_id, shape=shape_name, mesh=mesh_name,
                     kind=shape.kind, ok=False)
    try:
        t0 = time.monotonic()
        lowered, meta = lower_cell(
            arch_id, shape_name, mesh, microbatch_per_device, remat,
            cfg_override=cfg_override, shape_override=shape_override,
            serve_replicate_embed=serve_replicate_embed,
            kv_quant=kv_quant, grad_dtype=grad_dtype)
        res.lower_s = time.monotonic() - t0
        res.microbatches = meta.get("microbatches", 1)

        t0 = time.monotonic()
        compiled = lowered.compile()
        res.compile_s = time.monotonic() - t0

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):    # older jax: list of per-device dicts
            ca = ca[0] if ca else {}
        res.flops = float(ca.get("flops", 0.0))
        res.bytes_accessed = float(ca.get("bytes accessed", 0.0))

        ma = compiled.memory_analysis()
        if ma is not None:
            res.argument_bytes = float(
                getattr(ma, "argument_size_in_bytes", 0))
            res.output_bytes = float(getattr(ma, "output_size_in_bytes", 0))
            res.temp_bytes = float(getattr(ma, "temp_size_in_bytes", 0))
            res.peak_memory_per_device = (
                res.argument_bytes + res.temp_bytes)

        if with_hlo_stats:
            txt = compiled.as_text()
            stats = hlo_stats.analyze(txt)
            res.collectives = stats["collectives"]
            res.hlo_flops = stats["hlo_flops"]
            res.hlo_bytes = stats["hlo_bytes"]
        res.ok = True
    except Exception as e:  # noqa: BLE001 — recorded, cell marked failed
        res.error = f"{type(e).__name__}: {e}"[:2000]
    return res


def save_result(res: CellResult, out_dir: str) -> str:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir,
                        f"{res.arch}__{res.shape}__{res.mesh}.json")
    with open(path, "w") as f:
        json.dump(res.to_json(), f, indent=1)
    return path
