"""Serving driver: batched prefill + decode with optionally SLaB-
compressed weights.

  python -m repro.launch.serve --arch llama2_7b --smoke --compress slab \
      --batch 8 --prompt-len 64 --gen-len 32

  # mixed-method per-linear policy (plan DSL, JSON, or @file.json):
  python -m repro.launch.serve --arch deepseek_moe_16b \
      --plan 'attn.*=sparsegpt; moe.shared.*=slab@cr=0.4; *=slab'

  # sensitivity-driven per-layer CRs at a 0.5 global budget (one
  # calibration pass; equivalent: --plan '*=slab@auto; budget=0.5'):
  python -m repro.launch.serve --arch llama2_7b --budget 0.5

Pipeline: load/init params -> (optional) layer-wise compression driven
by a CompressionPlan with calibration data -> prefill the prompt batch
-> greedy decode. ``--compress <method>`` stays as sugar for the
single-rule plan ``*=<method>``; ``--plan`` takes anything
``CompressionPlan.parse`` accepts and wins when both are given;
``--budget`` routes either through ``core.allocator`` (water-filled
per-layer CRs from one calibration pass) and prints the per-layer CR
table. The compressed weights can be served either as dense-equivalent
swaps (XLA path) or through the fused Pallas kernel (--kernel,
interpret-mode on CPU; compiled Mosaic on TPU). ``--no-smoke`` reaches
the full-size configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compressor as compressor_lib
from repro.core.pipeline import compress_model
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import positions_for


def greedy_decode(cfg, params, prompts: jnp.ndarray, gen_len: int,
                  lengths=None):
    """Prefill + greedy generation in TWO dispatches: one ``lax.scan``
    over the prompt positions (the cache tracks its own write offset,
    so scanning the decode step is semantically identical to the old
    token-by-token Python loop — without its O(prompt_len) dispatch
    overhead) and one scanned generation loop. Runs under the ambient
    mesh (``meshctx.use_mesh``) when the caller entered one.

    ``lengths`` (B,) serves a right-padded ragged batch: row ``r``'s
    prompt is ``prompts[r, :lengths[r]]`` and its ``gen_len`` outputs
    start right after it. Implemented as ONE unified scan: at step t a
    row feeds its next prompt token while t < length, its previously
    sampled token after — every row's token stream stays contiguous
    from position 0, so the shared cache offset and positions are exact
    for all rows and no masking is needed. Short rows keep decoding
    past their budget (harmless; extra tokens are dropped by the final
    per-row gather)."""
    b, s = prompts.shape
    if lengths is not None:
        return _greedy_decode_ragged(cfg, params, prompts, gen_len,
                                     jnp.asarray(lengths, jnp.int32))
    s_max = s + gen_len
    cache = lm.init_cache(cfg, b, s_max)

    def step(cache, tok, pos):
        return lm.decode_step(cfg, params, cache, tok, pos)

    @jax.jit
    def prefill(cache, prompts, pos_all, logits0):
        def body(carry, xs):
            c, _ = carry
            tok, pos = xs
            pos = pos[:, None] if pos.ndim == 1 else pos[:, None, :]
            logits, c = step(c, tok[:, None], pos)
            return (c, logits[:, -1]), None
        xs = (jnp.moveaxis(prompts, 1, 0),
              jnp.moveaxis(pos_all, 1, 0))
        (cache, logits), _ = jax.lax.scan(body, (cache, logits0), xs)
        return cache, logits

    @jax.jit
    def generate(cache, last_logits):
        first = jnp.argmax(last_logits, -1)

        def body(carry, t):
            cache, tok = carry
            pos = positions_for(cfg, b, 1, offset=t)
            logits, cache = step(cache, tok[:, None], pos)
            nxt = jnp.argmax(logits[:, -1], -1)
            return (cache, nxt), nxt

        (cache, _), rest = jax.lax.scan(
            body, (cache, first), jnp.arange(s, s + gen_len - 1))
        return jnp.concatenate([first[:, None],
                                jnp.moveaxis(rest, 0, 1)], axis=1)

    sd = jax.eval_shape(step, cache, prompts[:, :1],
                        positions_for(cfg, b, 1))[0]
    logits0 = jnp.zeros((b, cfg.vocab), sd.dtype)
    cache, last_logits = prefill(cache, prompts,
                                 positions_for(cfg, b, s), logits0)
    return generate(cache, last_logits)


def _greedy_decode_ragged(cfg, params, prompts, gen_len, lengths):
    b, s = prompts.shape
    n_steps = s + gen_len - 1               # longest row: s-1 prompt
    cache = lm.init_cache(cfg, b, s + gen_len)  # steps + gen_len-1 more
    fed = jnp.concatenate(                  # prompt stream, zero-padded
        [prompts.astype(jnp.int32),
         jnp.zeros((b, n_steps - s), jnp.int32)], axis=1)

    @jax.jit
    def run(cache, fed, lengths):
        def body(carry, xs):
            cache, prev = carry
            ptok, t = xs
            tok = jnp.where(t < lengths, ptok, prev)
            pos = positions_for(cfg, b, 1, offset=t)
            logits, cache = lm.decode_step(cfg, params, cache,
                                           tok[:, None], pos)
            nxt = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            return (cache, nxt), nxt

        xs = (jnp.moveaxis(fed, 1, 0), jnp.arange(n_steps))
        _, ys = jax.lax.scan(body, (cache, jnp.zeros((b,), jnp.int32)), xs)
        sampled = jnp.moveaxis(ys, 0, 1)    # (B, n_steps)
        idx = lengths[:, None] - 1 + jnp.arange(gen_len)[None, :]
        return jnp.take_along_axis(sampled, idx, axis=1)

    return run(cache, fed, lengths)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced smoke geometry (--no-smoke for the "
                         "full-size config)")
    ap.add_argument("--compress",
                    choices=["none"] + compressor_lib.available(),
                    default="slab",
                    help="single-method sugar for --plan '*=<method>'")
    ap.add_argument("--plan", default=None,
                    help="CompressionPlan spec: inline DSL "
                         "('attn.*=sparsegpt; *=slab@cr=0.4'), JSON, or "
                         "@/path/to/plan.json; overrides --compress")
    ap.add_argument("--budget", type=float, default=None,
                    help="global CR budget: allocate per-layer CRs by "
                         "sensitivity water-filling (core.allocator) "
                         "over --plan/--compress, from one calibration "
                         "pass")
    ap.add_argument("--packed", action="store_true",
                    help="serve through the fused Pallas kernels (SLaB "
                         "on-HBM format; interpret mode on CPU)")
    ap.add_argument("--engine", action="store_true",
                    help="serve an open-loop request trace through the "
                         "continuous-batching engine (paged KV cache + "
                         "scheduler, docs/serving_engine.md) instead of "
                         "one static greedy_decode batch; composes with "
                         "--packed/--plan/--mesh")
    ap.add_argument("--requests", type=int, default=8,
                    help="--engine: requests in the synthetic trace")
    ap.add_argument("--block-size", type=int, default=16,
                    help="--engine: paged-cache tokens per block")
    ap.add_argument("--deadline", type=float, default=None,
                    help="--engine: per-request TTL in seconds — a "
                         "request not finished by arrival+TTL times "
                         "out (status 'timeout', partial output kept)")
    ap.add_argument("--max-waiting", type=int, default=None,
                    help="--engine: bound the waiting queue; overflow "
                         "arrivals are load-shed (status 'shed')")
    ap.add_argument("--shed", default="reject",
                    choices=["reject", "evict-oldest-waiting"],
                    help="--engine: load-shedding policy when "
                         "--max-waiting overflows")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="--engine: run under a seeded FaultPlan "
                         "(pool-shrink, forced NaNs, arrival burst — "
                         "serving/faults.py) to exercise the recovery "
                         "paths; same seed, same faults")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="run prefill+decode under a (data, model) "
                         "device mesh, e.g. --mesh 1,4: weights are "
                         "planner-placed and packed leaves are born "
                         "with their per-variant NamedShardings "
                         "(docs/packed_serving.md §Sharding)")
    ap.add_argument("--cr", type=float, default=0.5)
    ap.add_argument("--pattern", default=None)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--calib-seqs", type=int, default=16)
    ap.add_argument("--calib-batch", type=int, default=0,
                    help="stream calibration in chunks of this many "
                         "sequences (0 = single batch)")
    ap.add_argument("--calib-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    params, axes = lm.init(cfg, jax.random.PRNGKey(args.seed))
    print(f"{cfg.name}: {lm.param_count(cfg)/1e6:.2f}M params")

    mesh, planner = None, None
    if args.mesh:
        from repro.runtime.sharding import Planner
        d, m = (int(x) for x in args.mesh.split(","))
        if d * m > jax.device_count():
            ap.error(f"--mesh {args.mesh} needs {d * m} devices, have "
                     f"{jax.device_count()} (CPU: set XLA_FLAGS="
                     f"--xla_force_host_platform_device_count={d * m})")
        mesh = jax.make_mesh((d, m), ("data", "model"))
        planner = Planner(mesh, cfg)
        print(f"mesh: data={d} x model={m} over {d * m} devices")

    scfg = SLaBConfig(cr=args.cr, pattern=args.pattern, iters=args.iters)
    plan = (CompressionPlan.parse(args.plan, base=scfg)
            if args.plan else None)
    if args.budget is not None and plan is None and args.compress == "none":
        ap.error("--budget needs something to allocate: give --plan or "
                 "a --compress method")
    if plan is not None or args.compress != "none":
        calib = calibration_batch(cfg.vocab, seed=args.seed,
                                  n_seq=args.calib_seqs,
                                  seq_len=args.calib_len)
        if args.calib_batch:
            from repro.core.plan import CalibrationSpec
            calib = CalibrationSpec(calib, batch_size=args.calib_batch)
        t0 = time.monotonic()
        stats_pre = None
        if args.budget is not None:
            from repro.core.allocator import allocate_plan
            alloc = allocate_plan(
                cfg, params, calib, budget=args.budget,
                template=(plan if plan is not None
                          else f"*={args.compress}"), base=scfg)
            plan, stats_pre = alloc.plan, alloc.stats
            print(f"allocated {len(alloc.crs)} CR groups at budget "
                  f"{alloc.budget:.3f} (achieved {alloc.achieved:.3f}, "
                  f"one calibration pass, "
                  f"{alloc.stats.n_forwards} layer forwards)")
        out = compress_model(cfg, params, calib, method=args.compress,
                             scfg=scfg, plan=plan,
                             keep_decompositions=args.packed,
                             stats=stats_pre)
        params, stats = out[0], out[1]
        by_method = sorted({s.method for s in stats})
        cr_meas = float(np.mean([s.cr for s in stats])) if stats else 0.0
        print(f"compressed {len(stats)} linears "
              f"({'/'.join(by_method)}) at measured CR={cr_meas:.3f} "
              f"in {time.monotonic() - t0:.1f}s")
        if args.plan is not None or args.budget is not None:
            # per-layer CR table: allocator / plan decisions stay
            # observable without rerunning calibration
            print(f"{'layer':>5}  {'path':<20} {'method':<10} "
                  f"{'cr_req':>7} {'cr':>7} {'err_before':>11} "
                  f"{'err_after':>10}")
            for s in stats:
                print(f"{s.layer:>5}  {s.name:<20} {s.method:<10} "
                      f"{s.cr_requested:>7.3f} {s.cr:>7.3f} "
                      f"{s.err_before:>11.4g} {s.err_after:>10.4g}")
        if planner is not None:
            # place the (dense-equivalent) weights BEFORE packing so
            # packed leaves are born on the mesh, not resharded after
            params = jax.device_put(
                params, planner.tree_shardings(axes, params))
        if args.packed:
            from repro.core.packed_model import pack_plan_decs
            eff_plan = (plan if plan is not None
                        else CompressionPlan.parse(f"*={args.compress}",
                                                   base=scfg))
            params, rep = pack_plan_decs(
                params, out[2], cfg.n_layers, eff_plan, dtype=cfg.dtype,
                variants={(s.layer, s.name): s.variant for s in stats},
                planner=planner)
            if rep.n_packed:
                variants = " ".join(
                    f"{v}={c}" for v, c in sorted(rep.by_variant.items()))
                print(f"packed serving: {rep.n_packed} linears on the "
                      f"fused kernel path across {len(rep.paths)} paths "
                      f"[{variants}]; dense fallback: {len(rep.fallback)}")
                if rep.fallback:
                    print("  dense-fallback linears:",
                          ", ".join(f"L{l}/{p}" for l, p in rep.fallback))
                print(f"segment layout: {len(rep.segments)} scan "
                      f"segment(s) over {cfg.n_layers} layers")
                for seg in rep.segments:
                    span = (f"L{seg.lo}" if seg.hi == seg.lo + 1
                            else f"L{seg.lo}-L{seg.hi - 1}")
                    print(f"  {span}: " + "  ".join(
                        f"{p}={d}" for p, d in seg.sig))
                for var, (pb, db) in sorted(rep.bytes_by_variant.items()):
                    flag = "  <-- exceeds dense" if pb > db else ""
                    print(f"  bytes/{var}: {pb / 1e3:.1f} kB packed vs "
                          f"{db / 1e3:.1f} kB dense "
                          f"({pb / db:.2f}x){flag}")
            else:
                print("--packed: plan produced no packable "
                      "decompositions; serving dense-equivalent weights")

    else:
        if planner is not None:
            params = jax.device_put(
                params, planner.tree_shardings(axes, params))

    from repro.runtime.meshctx import use_mesh

    if args.engine:
        from repro.serving import Engine, EngineConfig, Request
        from repro.serving.engine import summarize
        from repro.serving.paged_cache import blocks_needed
        rng = np.random.default_rng(args.seed)
        reqs = []
        t_arr = 0.0
        for i in range(args.requests):
            p_len = int(rng.integers(max(args.prompt_len // 2, 1),
                                     args.prompt_len + 1))
            n_new = int(rng.integers(max(args.gen_len // 2, 1),
                                     args.gen_len + 1))
            reqs.append(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab, size=p_len),
                max_new=n_new, arrival=t_arr,
                deadline=(t_arr + args.deadline
                          if args.deadline is not None else None)))
            t_arr += float(rng.exponential(0.2))
        max_len = args.prompt_len + args.gen_len
        per_req = blocks_needed(max_len, args.block_size)
        ecfg = EngineConfig(
            n_slots=args.batch, block_size=args.block_size,
            n_blocks=per_req * args.batch, max_len=max_len,
            prefill_chunk=min(8, args.prompt_len),
            max_waiting=args.max_waiting, shed=args.shed)
        eng = Engine(cfg, params, ecfg, mesh=mesh, planner=planner)
        faults = None
        if args.chaos is not None:
            from repro.serving.faults import FaultPlan
            faults = FaultPlan.chaos(args.chaos, vocab=cfg.vocab,
                                     n_rows=args.batch)
            print(f"chaos: {faults!r}")
        t0 = time.monotonic()
        done = eng.run(reqs, clock="wall", faults=faults)
        m = summarize(done, time.monotonic() - t0)
        statuses = " ".join(f"{k}={v}" for k, v
                            in sorted(m["statuses"].items()))
        print(f"engine: {m['n_requests']} requests [{statuses}], "
              f"{m['n_tokens_out']} tokens in {m['wall_s']:.1f}s "
              f"({m['tokens_per_s']:.1f} tok/s, goodput "
              f"{m['goodput_tokens_per_s']:.1f} tok/s, "
              f"{eng.n_steps} steps, {m['n_evictions']} evictions)")
        print(f"  ttft p50/p95/p99: {m['ttft']['p50']:.3f}/"
              f"{m['ttft']['p95']:.3f}/{m['ttft']['p99']:.3f}s")
        lat = m['per_token_latency']
        print(f"  per-token p50/p95/p99: {lat['p50'] * 1e3:.1f}/"
              f"{lat['p95'] * 1e3:.1f}/{lat['p99'] * 1e3:.1f}ms")
        print("sample generation:",
              np.asarray(reqs[0].out, np.int32)[:16])
        return

    corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)
    prompts = jnp.asarray(
        corpus.batch(0, args.batch, args.prompt_len)["inputs"])
    t0 = time.monotonic()
    with use_mesh(mesh):
        gen = greedy_decode(cfg, params, prompts, args.gen_len)
        jax.block_until_ready(gen)
    dt = time.monotonic() - t0
    n_tok = args.batch * (args.prompt_len + args.gen_len)
    print(f"served {args.batch} seqs x ({args.prompt_len}+{args.gen_len}) "
          f"tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s)")
    print("sample generation:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
