"""Serving driver: batched prefill + decode with optionally SLaB-
compressed weights.

  python -m repro.launch.serve --arch llama2_7b --smoke --compress slab \
      --batch 8 --prompt-len 64 --gen-len 32

Pipeline: load/init params -> (optional) layer-wise SLaB compression
with calibration data -> prefill the prompt batch -> greedy decode.
The compressed weights can be served either as dense-equivalent swaps
(XLA path) or through the fused Pallas kernel (--kernel, interpret-mode
on CPU; compiled Mosaic on TPU).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.pipeline import compress_model
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import positions_for


def greedy_decode(cfg, params, prompts: jnp.ndarray, gen_len: int):
    b, s = prompts.shape
    s_max = s + gen_len
    cache = lm.init_cache(cfg, b, s_max)
    dec = jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p))

    # prefill token-by-token through the decode path (exercises the cache
    # exactly as production would; a fused prefill is launch-side work)
    tok = prompts[:, :1]
    logits = None
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        logits, cache = dec(cache, prompts[:, t:t + 1], pos)
    out = [jnp.argmax(logits[:, -1], -1)]
    for t in range(s, s + gen_len - 1):
        pos = positions_for(cfg, b, 1, offset=t)
        logits, cache = dec(cache, out[-1][:, None], pos)
        out.append(jnp.argmax(logits[:, -1], -1))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--compress", choices=["none", "slab", "wanda",
                                           "magnitude", "sparsegpt"],
                    default="slab")
    ap.add_argument("--packed", action="store_true",
                    help="serve through the fused Pallas kernels (SLaB "
                         "on-HBM format; interpret mode on CPU)")
    ap.add_argument("--cr", type=float, default=0.5)
    ap.add_argument("--pattern", default=None)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--calib-seqs", type=int, default=16)
    ap.add_argument("--calib-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    params, _ = lm.init(cfg, jax.random.PRNGKey(args.seed))
    print(f"{cfg.name}: {lm.param_count(cfg)/1e6:.2f}M params")

    if args.compress != "none":
        calib = calibration_batch(cfg.vocab, seed=args.seed,
                                  n_seq=args.calib_seqs,
                                  seq_len=args.calib_len)
        t0 = time.monotonic()
        scfg = SLaBConfig(cr=args.cr, pattern=args.pattern,
                          iters=args.iters)
        keep = args.packed and args.compress == "slab"
        out = compress_model(cfg, params, calib, method=args.compress,
                             scfg=scfg, keep_decompositions=keep)
        params, stats = out[0], out[1]
        print(f"compressed {len(stats)} linears at CR={args.cr} "
              f"in {time.monotonic() - t0:.1f}s")
        if keep:
            from repro.core.packed_model import pack_model
            params = pack_model(params, out[2], cfg.n_layers,
                                pattern=args.pattern)
            print("serving through fused Pallas kernels "
                  "(SLaB packed on-HBM format)")

    corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)
    prompts = jnp.asarray(
        corpus.batch(0, args.batch, args.prompt_len)["inputs"])
    t0 = time.monotonic()
    gen = greedy_decode(cfg, params, prompts, args.gen_len)
    dt = time.monotonic() - t0
    n_tok = args.batch * (args.prompt_len + args.gen_len)
    print(f"served {args.batch} seqs x ({args.prompt_len}+{args.gen_len}) "
          f"tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s)")
    print("sample generation:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
