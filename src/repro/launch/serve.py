"""Serving driver: batched prefill + decode with optionally SLaB-
compressed weights.

  python -m repro.launch.serve --arch llama2_7b --smoke --compress slab \
      --batch 8 --prompt-len 64 --gen-len 32

  # mixed-method per-linear policy (plan DSL, JSON, or @file.json):
  python -m repro.launch.serve --arch deepseek_moe_16b \
      --plan 'attn.*=sparsegpt; moe.shared.*=slab@cr=0.4; *=slab'

  # sensitivity-driven per-layer CRs at a 0.5 global budget (one
  # calibration pass; equivalent: --plan '*=slab@auto; budget=0.5'):
  python -m repro.launch.serve --arch llama2_7b --budget 0.5

Pipeline: load/init params -> (optional) layer-wise compression driven
by a CompressionPlan with calibration data -> prefill the prompt batch
-> greedy decode. ``--compress <method>`` stays as sugar for the
single-rule plan ``*=<method>``; ``--plan`` takes anything
``CompressionPlan.parse`` accepts and wins when both are given;
``--budget`` routes either through ``core.allocator`` (water-filled
per-layer CRs from one calibration pass) and prints the per-layer CR
table. The compressed weights can be served either as dense-equivalent
swaps (XLA path) or through the fused Pallas kernel (--kernel,
interpret-mode on CPU; compiled Mosaic on TPU). ``--no-smoke`` reaches
the full-size configs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compressor as compressor_lib
from repro.core.pipeline import compress_model
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import positions_for


def greedy_decode(cfg, params, prompts: jnp.ndarray, gen_len: int):
    b, s = prompts.shape
    s_max = s + gen_len
    cache = lm.init_cache(cfg, b, s_max)
    dec = jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p))

    # prefill token-by-token through the decode path (exercises the cache
    # exactly as production would; a fused prefill is launch-side work)
    tok = prompts[:, :1]
    logits = None
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        logits, cache = dec(cache, prompts[:, t:t + 1], pos)
    out = [jnp.argmax(logits[:, -1], -1)]
    for t in range(s, s + gen_len - 1):
        pos = positions_for(cfg, b, 1, offset=t)
        logits, cache = dec(cache, out[-1][:, None], pos)
        out.append(jnp.argmax(logits[:, -1], -1))
    return jnp.stack(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b")
    ap.add_argument("--smoke", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="reduced smoke geometry (--no-smoke for the "
                         "full-size config)")
    ap.add_argument("--compress",
                    choices=["none"] + compressor_lib.available(),
                    default="slab",
                    help="single-method sugar for --plan '*=<method>'")
    ap.add_argument("--plan", default=None,
                    help="CompressionPlan spec: inline DSL "
                         "('attn.*=sparsegpt; *=slab@cr=0.4'), JSON, or "
                         "@/path/to/plan.json; overrides --compress")
    ap.add_argument("--budget", type=float, default=None,
                    help="global CR budget: allocate per-layer CRs by "
                         "sensitivity water-filling (core.allocator) "
                         "over --plan/--compress, from one calibration "
                         "pass")
    ap.add_argument("--packed", action="store_true",
                    help="serve through the fused Pallas kernels (SLaB "
                         "on-HBM format; interpret mode on CPU)")
    ap.add_argument("--cr", type=float, default=0.5)
    ap.add_argument("--pattern", default=None)
    ap.add_argument("--iters", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--calib-seqs", type=int, default=16)
    ap.add_argument("--calib-batch", type=int, default=0,
                    help="stream calibration in chunks of this many "
                         "sequences (0 = single batch)")
    ap.add_argument("--calib-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=args.smoke)
    params, _ = lm.init(cfg, jax.random.PRNGKey(args.seed))
    print(f"{cfg.name}: {lm.param_count(cfg)/1e6:.2f}M params")

    scfg = SLaBConfig(cr=args.cr, pattern=args.pattern, iters=args.iters)
    plan = (CompressionPlan.parse(args.plan, base=scfg)
            if args.plan else None)
    if args.budget is not None and plan is None and args.compress == "none":
        ap.error("--budget needs something to allocate: give --plan or "
                 "a --compress method")
    if plan is not None or args.compress != "none":
        calib = calibration_batch(cfg.vocab, seed=args.seed,
                                  n_seq=args.calib_seqs,
                                  seq_len=args.calib_len)
        if args.calib_batch:
            from repro.core.plan import CalibrationSpec
            calib = CalibrationSpec(calib, batch_size=args.calib_batch)
        t0 = time.monotonic()
        stats_pre = None
        if args.budget is not None:
            from repro.core.allocator import allocate_plan
            alloc = allocate_plan(
                cfg, params, calib, budget=args.budget,
                template=(plan if plan is not None
                          else f"*={args.compress}"), base=scfg)
            plan, stats_pre = alloc.plan, alloc.stats
            print(f"allocated {len(alloc.crs)} CR groups at budget "
                  f"{alloc.budget:.3f} (achieved {alloc.achieved:.3f}, "
                  f"one calibration pass, "
                  f"{alloc.stats.n_forwards} layer forwards)")
        out = compress_model(cfg, params, calib, method=args.compress,
                             scfg=scfg, plan=plan,
                             keep_decompositions=args.packed,
                             stats=stats_pre)
        params, stats = out[0], out[1]
        by_method = sorted({s.method for s in stats})
        cr_meas = float(np.mean([s.cr for s in stats])) if stats else 0.0
        print(f"compressed {len(stats)} linears "
              f"({'/'.join(by_method)}) at measured CR={cr_meas:.3f} "
              f"in {time.monotonic() - t0:.1f}s")
        if args.plan is not None or args.budget is not None:
            # per-layer CR table: allocator / plan decisions stay
            # observable without rerunning calibration
            print(f"{'layer':>5}  {'path':<20} {'method':<10} "
                  f"{'cr_req':>7} {'cr':>7} {'err_before':>11} "
                  f"{'err_after':>10}")
            for s in stats:
                print(f"{s.layer:>5}  {s.name:<20} {s.method:<10} "
                      f"{s.cr_requested:>7.3f} {s.cr:>7.3f} "
                      f"{s.err_before:>11.4g} {s.err_after:>10.4g}")
        if args.packed:
            from repro.core.packed_model import pack_plan_decs
            eff_plan = (plan if plan is not None
                        else CompressionPlan.parse(f"*={args.compress}",
                                                   base=scfg))
            params, rep = pack_plan_decs(
                params, out[2], cfg.n_layers, eff_plan, dtype=cfg.dtype,
                variants={(s.layer, s.name): s.variant for s in stats})
            if rep.n_packed:
                variants = " ".join(
                    f"{v}={c}" for v, c in sorted(rep.by_variant.items()))
                print(f"packed serving: {rep.n_packed} linears on the "
                      f"fused kernel path across {len(rep.paths)} paths "
                      f"[{variants}]; dense fallback: {len(rep.fallback)}")
                if rep.fallback:
                    print("  dense-fallback linears:",
                          ", ".join(f"L{l}/{p}" for l, p in rep.fallback))
                print(f"segment layout: {len(rep.segments)} scan "
                      f"segment(s) over {cfg.n_layers} layers")
                for seg in rep.segments:
                    span = (f"L{seg.lo}" if seg.hi == seg.lo + 1
                            else f"L{seg.lo}-L{seg.hi - 1}")
                    print(f"  {span}: " + "  ".join(
                        f"{p}={d}" for p, d in seg.sig))
                for var, (pb, db) in sorted(rep.bytes_by_variant.items()):
                    flag = "  <-- exceeds dense" if pb > db else ""
                    print(f"  bytes/{var}: {pb / 1e3:.1f} kB packed vs "
                          f"{db / 1e3:.1f} kB dense "
                          f"({pb / db:.2f}x){flag}")
            else:
                print("--packed: plan produced no packable "
                      "decompositions; serving dense-equivalent weights")

    corpus = SyntheticCorpus(cfg.vocab, seed=args.seed)
    prompts = jnp.asarray(
        corpus.batch(0, args.batch, args.prompt_len)["inputs"])
    t0 = time.monotonic()
    gen = greedy_decode(cfg, params, prompts, args.gen_len)
    dt = time.monotonic() - t0
    n_tok = args.batch * (args.prompt_len + args.gen_len)
    print(f"served {args.batch} seqs x ({args.prompt_len}+{args.gen_len}) "
          f"tokens in {dt:.1f}s ({n_tok/dt:.1f} tok/s)")
    print("sample generation:", np.asarray(gen[0])[:16])


if __name__ == "__main__":
    main()
