"""Layer-wise one-shot compression driver (the SparseGPT/Wanda protocol
the paper follows, §II-A1), with calibration statistics sourced from
**activation taps** — not from re-implemented layer math.

  for each transformer layer, in order:
    (1) forward the calibration set through the *already-compressed*
        prefix to the layer's inputs,
    (2) run the layer's REAL forward (``models.lm._layer_fwd``) under
        ``models.common.tap_capture``: the ``linear()`` dispatch
        chokepoint reports every linear's exact input, reduced on the
        fly to ‖X‖₂ column norms and (for SparseGPT / when requested)
        X^T X Hessians,
    (3) decompose every linear in the layer (SLaB / a baseline) from
        those tapped stats,
    (4) replace the weights and continue forward with the compressed
        layer's outputs (error propagation).

The tap protocol: modules name their linears (``linear(x, w,
tap="wq")``) under scope prefixes pushed by the layer assembly
("attn", "mlp", "moe", "moe.shared", "mamba"), so tap names equal the
``linear_paths`` entries below by construction. One source of truth —
attention, MoE dispatch (per-expert stats see exactly the
dispatched-token subsets, capacity drops included), the Mamba-2 SSD
scan, and the hybrid shared block are never re-derived here, every
family gets exact ``attn.wo``-style downstream stats, and Hessians are
available for all families (dense, MoE per-expert, SSM, hybrid).
Future scoring variants (HASSLE-free alternating updates, SoLA-style
soft sparsity) plug in at the same chokepoint without touching model
code.

Works on the model zoo's stacked-params layout: weights live as
``params["layers"][...]`` leaves with a leading L dim; we slice layer l,
compress its 2-D linears, and write them back. MoE experts are
compressed per-expert with expert-specific activation statistics: the
dispatched-token subset that actually reaches each expert is what feeds
its ‖X‖₂ and X^T X.

Per the paper, embeddings and the LM head are excluded (§III-A4); norms,
biases and other 1-D leaves are untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as base_lib
from repro.core import scores as scores_lib
from repro.core.slab import SLaBConfig, slab_decompose, reconstruct
from repro.models import lm
from repro.models.common import ArchConfig, positions_for, tap_capture

Array = jax.Array


@dataclasses.dataclass
class CompressStats:
    layer: int
    name: str
    err_before: float   # ‖W diag(n)‖_F — the zero-approximation baseline
    err_after: float    # ‖(W - Ŵ) diag(n)‖_F with the same tapped norms
    cr: float


def _get(d: dict, path: str):
    cur = d
    for k in path.split("."):
        if k not in cur:
            return None
        cur = cur[k]
    return cur


def _set(d: dict, path: str, val):
    ks = path.split(".")
    cur = d
    for k in ks[:-1]:
        cur = cur[k]
    cur[ks[-1]] = val


def linear_paths(cfg: ArchConfig) -> List[str]:
    """Compressible 2-D linears inside one layer of this family."""
    if cfg.family in ("ssm", "hybrid"):
        return ["mamba.in_z", "mamba.in_x", "mamba.out"]
    paths = ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]
    if cfg.family == "moe":
        paths += ["moe.w_gate", "moe.w_up", "moe.w_down"]  # (E, D, F) 3-D
        if cfg.shared_ff:
            paths += ["moe.shared.w_gate", "moe.shared.w_up",
                      "moe.shared.w_down"]
    elif cfg.act == "swiglu":
        paths += ["mlp.w_gate", "mlp.w_up", "mlp.w_down"]
    else:
        paths += ["mlp.w_up", "mlp.w_down"]
    return paths


def layer_tap_stats(cfg: ArchConfig, params: dict, lp: dict, idx: int,
                    h: Array, positions: Array, hessian: bool = False
                    ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """Run layer ``idx``'s real forward under an activation-tap capture.

    Returns ``(act_norms, hessians)`` keyed by ``linear_paths`` names:
    norms are (D_in,) — stacked (E, D_in) for MoE experts — and
    Hessians X^T X are (D_in, D_in) / (E, D_in, D_in); ``hessians`` is
    empty unless ``hessian=True``.
    """
    with tap_capture(hessian=hessian,
                     hessian_names=set(linear_paths(cfg))) as tap:
        lm._layer_fwd(cfg, params, lp, jnp.asarray(idx), h, positions)
    acts: Dict[str, Array] = {}
    hess: Dict[str, Array] = {}
    for pth in linear_paths(cfg):
        if not tap.has(pth):
            continue
        acts[pth] = tap.norms(pth)
        hz = tap.hessian(pth)
        if hz is not None:
            hess[pth] = hz
    return acts, hess


def _compress_matrix(w: Array, act_norms: Optional[Array], method: str,
                     scfg: SLaBConfig, hessian: Optional[Array] = None
                     ) -> Tuple[Array, Optional[object]]:
    """Returns (compressed dense equivalent, SLaBDecomposition or None).
    ``w`` is stored (D_in, D_out) in our models — transpose to the
    paper's (D_out, D_in) convention and back."""
    wt = w.T.astype(jnp.float32)
    dec = None
    if method == "slab":
        dec = slab_decompose(wt, act_norms, scfg)
        out = reconstruct(dec)
    elif method == "wanda":
        # Wanda at CR c keeps (1-c) of weights (no side components)
        out = base_lib.wanda_prune(
            wt, act_norms if act_norms is not None
            else jnp.ones((wt.shape[1],), jnp.float32),
            1.0 - scfg.cr, group=scfg.group, pattern=scfg.pattern)
    elif method == "sparsegpt":
        assert hessian is not None
        out = base_lib.sparsegpt_prune(wt, hessian, 1.0 - scfg.cr,
                                       pattern=scfg.pattern)
    elif method == "magnitude":
        out = base_lib.magnitude_prune(wt, 1.0 - scfg.cr,
                                       group=scfg.group,
                                       pattern=scfg.pattern)
    else:
        raise ValueError(method)
    return out.T.astype(w.dtype), dec


def _expert_hessian(hess: Optional[Array], e: int, d_in: int
                    ) -> Optional[Array]:
    """Slice expert ``e``'s Hessian; an expert that saw no calibration
    tokens (all-zero Gram) falls back to the identity, which reduces
    SparseGPT to magnitude pruning instead of zeroing the expert."""
    if hess is None:
        return None
    hz = hess[e] if hess.ndim == 3 else hess
    if float(jnp.trace(hz)) == 0.0:
        return jnp.eye(d_in, dtype=jnp.float32)
    return hz


def _weighted_errs(w: Array, w_new: Array, an: Optional[Array]
                   ) -> Tuple[float, float]:
    """(err_before, err_after): activation-weighted Frobenius error of
    the zero approximation (the pre-compression baseline — what a layer
    would lose if the linear were dropped entirely) and of the actual
    reconstruction, both under the same tapped norms."""
    wt = w.T.astype(jnp.float32)
    zero = jnp.zeros_like(wt)
    err_b = float(scores_lib.weighted_fro_error(wt, zero, an))
    err_a = float(scores_lib.weighted_fro_error(
        wt, w_new.T.astype(jnp.float32), an))
    return err_b, err_a


def compress_model(cfg: ArchConfig, params: dict, calib_tokens: np.ndarray,
                   method: str = "slab",
                   scfg: SLaBConfig = SLaBConfig(),
                   collect_hessian: bool = False,
                   progress: Optional[Callable[[str], None]] = None,
                   keep_decompositions: bool = False):
    """Run the layer-wise protocol. Returns (new params, stats[, decs]).

    ``calib_tokens`` (N, S) int32 (or (N, S, D) embeds for stub-frontend
    families). Hessians (X^T X) are tapped only for SparseGPT (or when
    ``collect_hessian`` forces it) — for every family, including MoE
    (per-expert) and SSM. ``keep_decompositions`` additionally returns
    {(layer, path): dec} for core.packed_model.pack_model (kernel-served
    packed weights)."""
    stats: List[CompressStats] = []
    decs: Dict[Tuple[int, str], object] = {}
    x = jnp.asarray(calib_tokens)
    h = lm.embed_inputs(cfg, params, x)
    b, s = h.shape[0], h.shape[1]
    positions = positions_for(cfg, b, s)
    new_layers = jax.tree.map(lambda a: a, params["layers"])  # shallow copy
    want_hess = collect_hessian or method == "sparsegpt"

    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        acts, hess = layer_tap_stats(cfg, params, lp, l, h, positions,
                                     hessian=want_hess)

        for pth in linear_paths(cfg):
            w = _get(lp, pth)
            if w is None:
                continue
            an = acts.get(pth)
            if w.ndim == 3:        # MoE experts (E, D, F): per-expert
                outs, eb2, ea2 = [], 0.0, 0.0
                for e in range(w.shape[0]):
                    an_e = an[e] if (an is not None and an.ndim == 2) else an
                    o, _ = _compress_matrix(
                        w[e], an_e, method, scfg,
                        _expert_hessian(hess.get(pth), e, w.shape[1]))
                    outs.append(o)
                    b_e, a_e = _weighted_errs(w[e], o, an_e)
                    eb2 += b_e ** 2
                    ea2 += a_e ** 2
                w_new = jnp.stack(outs)
                err_b, err_a = float(np.sqrt(eb2)), float(np.sqrt(ea2))
            else:
                w_new, dec = _compress_matrix(w, an, method, scfg,
                                              hess.get(pth))
                if keep_decompositions and dec is not None:
                    decs[(l, pth)] = dec
                err_b, err_a = _weighted_errs(w, w_new, an)
            stats.append(CompressStats(l, pth, err_b, err_a, scfg.cr))
            _set(lp, pth, w_new)

        # write back and propagate through the *compressed* layer
        new_layers = jax.tree.map(
            lambda buf, leaf: buf.at[l].set(leaf), new_layers, lp)
        h, _ = lm._layer_fwd(cfg, params, lp, jnp.asarray(l), h, positions)
        if progress:
            progress(f"layer {l + 1}/{cfg.n_layers} compressed")

    out = dict(params)
    out["layers"] = new_layers
    if keep_decompositions:
        return out, stats, decs
    return out, stats
