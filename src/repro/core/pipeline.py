"""Layer-wise one-shot compression driver (the SparseGPT/Wanda protocol
the paper follows, §II-A1):

  for each transformer layer, in order:
    (1) forward the calibration set through the *already-compressed*
        prefix to the layer's inputs,
    (2) capture per-linear input activations -> ‖X‖₂ column norms,
    (3) decompose every linear in the layer (SLaB / a baseline),
    (4) replace the weights and continue forward with the compressed
        layer's outputs (error propagation).

Works on the model zoo's stacked-params layout: weights live as
``params["layers"][...]`` leaves with a leading L dim; we slice layer l,
compress its 2-D linears, and write them back. MoE experts are
compressed per-expert with expert-specific activation statistics
(DESIGN.md §4): the dispatched-token subset that actually reaches each
expert is what feeds its ‖X‖₂.

Per the paper, embeddings and the LM head are excluded (§III-A4); norms,
biases and other 1-D leaves are untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as base_lib
from repro.core import scores as scores_lib
from repro.core.slab import SLaBConfig, slab_decompose, reconstruct
from repro.models import lm
from repro.models.common import ArchConfig, positions_for, rms_norm

Array = jax.Array

# 2-D weight leaves eligible for compression, per layer family.
# (path within one layer's params dict, input-activation source)
DENSE_LINEARS = ["attn.wq", "attn.wk", "attn.wv", "attn.wo",
                 "mlp.w_gate", "mlp.w_up", "mlp.w_down"]


@dataclasses.dataclass
class CompressStats:
    layer: int
    name: str
    err_before: float
    err_after: float
    cr: float


def _get(d: dict, path: str):
    cur = d
    for k in path.split("."):
        if k not in cur:
            return None
        cur = cur[k]
    return cur


def _set(d: dict, path: str, val):
    ks = path.split(".")
    cur = d
    for k in ks[:-1]:
        cur = cur[k]
    cur[ks[-1]] = val


def linear_paths(cfg: ArchConfig) -> List[str]:
    """Compressible 2-D linears inside one layer of this family."""
    if cfg.family in ("ssm", "hybrid"):
        return ["mamba.in_z", "mamba.in_x", "mamba.out"]
    paths = ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]
    if cfg.family == "moe":
        paths += ["moe.w_gate", "moe.w_up", "moe.w_down"]  # (E, D, F) 3-D
        if cfg.shared_ff:
            paths += ["moe.shared.w_gate", "moe.shared.w_up",
                      "moe.shared.w_down"]
    elif cfg.act == "swiglu":
        paths += ["mlp.w_gate", "mlp.w_up", "mlp.w_down"]
    else:
        paths += ["mlp.w_up", "mlp.w_down"]
    return paths


def _compress_matrix(w: Array, act_norms: Optional[Array], method: str,
                     scfg: SLaBConfig, hessian: Optional[Array] = None
                     ) -> Tuple[Array, Optional[object]]:
    """Returns (compressed dense equivalent, SLaBDecomposition or None).
    ``w`` is stored (D_in, D_out) in our models — transpose to the
    paper's (D_out, D_in) convention and back."""
    wt = w.T.astype(jnp.float32)
    dec = None
    if method == "slab":
        dec = slab_decompose(wt, act_norms, scfg)
        out = reconstruct(dec)
    elif method == "wanda":
        # Wanda at CR c keeps (1-c) of weights (no side components)
        out = base_lib.wanda_prune(
            wt, act_norms if act_norms is not None
            else jnp.ones((wt.shape[1],), jnp.float32),
            1.0 - scfg.cr, group=scfg.group, pattern=scfg.pattern)
    elif method == "sparsegpt":
        assert hessian is not None
        out = base_lib.sparsegpt_prune(wt, hessian, 1.0 - scfg.cr,
                                       pattern=scfg.pattern)
    elif method == "magnitude":
        out = base_lib.magnitude_prune(wt, 1.0 - scfg.cr,
                                       group=scfg.group,
                                       pattern=scfg.pattern)
    else:
        raise ValueError(method)
    return out.T.astype(w.dtype), dec


def _layer_activations(cfg: ArchConfig, params: dict, lp: dict, idx: int,
                       h: Array, positions: Array) -> Dict[str, Array]:
    """Column-norm stats for every linear in layer ``idx`` given the
    layer input h (N, S, D). Mirrors models.lm._layer_fwd wiring."""
    stats: Dict[str, Array] = {}

    def note(path: str, x: Array):
        stats[path] = scores_lib.act_col_norms(x)

    if cfg.family in ("ssm", "hybrid"):
        hn = rms_norm(h, lp["norm"], cfg.norm_eps)
        note("mamba.in_z", hn)
        note("mamba.in_x", hn)
        # out_proj input: the gated/normalized y — recompute block pieces
        from repro.models import mamba2 as mamba_lib
        b, s, _ = hn.shape
        z = hn @ lp["mamba"]["in_z"]
        xs = jax.nn.silu(mamba_lib._causal_conv(
            hn @ lp["mamba"]["in_x"], lp["mamba"]["conv_x"]))
        bmat = jax.nn.silu(mamba_lib._causal_conv(
            hn @ lp["mamba"]["in_b"], lp["mamba"]["conv_b"]))
        cmat = jax.nn.silu(mamba_lib._causal_conv(
            hn @ lp["mamba"]["in_c"], lp["mamba"]["conv_c"]))
        dt = jax.nn.softplus(hn.astype(jnp.float32) @ lp["mamba"]["in_dt"]
                             + lp["mamba"]["dt_bias"])
        a = -jnp.exp(lp["mamba"]["a_log"])
        xh = xs.reshape(b, s, cfg.ssm_heads, cfg.ssm_headdim)
        y, _ = mamba_lib._ssd_chunk_scan(xh, dt, a, bmat, cmat,
                                         cfg.ssm_chunk)
        y = y + xh.astype(jnp.float32) * lp["mamba"]["d_skip"][None, None, :, None]
        y = y.reshape(b, s, cfg.d_inner).astype(cfg.dtype)
        y = rms_norm(y * jax.nn.silu(z), lp["mamba"]["gate_norm"],
                     cfg.norm_eps)
        note("mamba.out", y)
        return stats

    hn = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    for pth in ("attn.wq", "attn.wk", "attn.wv"):
        note(pth, hn)
    # wo input: attention context
    from repro.models import attention as attn_lib
    ctx_out = attn_lib.multihead_attention(cfg, lp["attn"], hn, positions)
    # recover pre-wo input: rerun without wo — cheaper: note via hook-free
    # recompute of the context (wo input = out before @wo)
    b, s, _ = hn.shape
    h2 = h + ctx_out
    hm = rms_norm(h2, lp["mlp_norm"], cfg.norm_eps)
    # context (pre-wo) activation: approximate with hn-driven recompute
    ctx = _attention_context(cfg, lp["attn"], hn, positions)
    note("attn.wo", ctx)

    if cfg.family == "moe":
        note("moe.w_gate", hm)   # per-expert stats refined below
        note("moe.w_up", hm)
        from repro.models import moe as moe_lib
        probs = jax.nn.softmax(
            (hm.reshape(-1, hm.shape[-1]).astype(jnp.float32)
             @ lp["moe"]["router"].astype(jnp.float32)), axis=-1)
        top = jnp.argsort(-probs, axis=-1)[:, :cfg.top_k]
        flat = hm.reshape(-1, hm.shape[-1]).astype(jnp.float32)
        e_norms, h_norms = [], []
        for e in range(cfg.n_experts):
            sel = jnp.any(top == e, axis=-1)
            xe = flat * sel[:, None]
            e_norms.append(jnp.sqrt(jnp.sum(xe * xe, axis=0)))
            he = jax.nn.silu(xe @ lp["moe"]["w_gate"][e]) * \
                (xe @ lp["moe"]["w_up"][e])
            h_norms.append(jnp.sqrt(jnp.sum(
                he.astype(jnp.float32) ** 2, axis=0)))
        stats["moe.w_gate"] = jnp.stack(e_norms)       # (E, D)
        stats["moe.w_up"] = jnp.stack(e_norms)
        stats["moe.w_down"] = jnp.stack(h_norms)       # (E, F)
        if cfg.shared_ff:
            note("moe.shared.w_gate", hm)
            note("moe.shared.w_up", hm)
            sh = jax.nn.silu(hm @ lp["moe"]["shared"]["w_gate"]) * \
                (hm @ lp["moe"]["shared"]["w_up"])
            note("moe.shared.w_down", sh)
    else:
        note("mlp.w_gate", hm)
        note("mlp.w_up", hm)
        if cfg.act == "swiglu":
            mid = jax.nn.silu(hm @ lp["mlp"]["w_gate"]) * \
                (hm @ lp["mlp"]["w_up"])
        else:
            from repro.models.common import activation
            kind = "gelu" if cfg.act == "gelu" else "relu2"
            mid = activation(hm @ lp["mlp"]["w_up"], kind)
        note("mlp.w_down", mid)
    return stats


def _attention_context(cfg, ap, hn, positions):
    """Pre-wo attention context (B, S, d_q)."""
    import types
    from repro.models import attention as attn_lib
    # rerun attention but stop before wo: reuse internals
    b, s, d = hn.shape
    h_, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    g = h_ // kv
    from repro.models.common import rotate
    q = (hn @ ap["wq"]).reshape(b, s, h_, dh)
    k = (hn @ ap["wk"]).reshape(b, s, kv, dh)
    v = (hn @ ap["wv"]).reshape(b, s, kv, dh)
    q = rotate(cfg, q, positions)
    k = rotate(cfg, k, positions)
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = q * (dh ** -0.5)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    if cfg.causal:
        ii = jnp.arange(s)
        logits = jnp.where((ii[:, None] >= ii[None, :])[None, None],
                           logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(cfg.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(b, s, cfg.d_q)


def compress_model(cfg: ArchConfig, params: dict, calib_tokens: np.ndarray,
                   method: str = "slab",
                   scfg: SLaBConfig = SLaBConfig(),
                   collect_hessian: bool = False,
                   progress: Optional[Callable[[str], None]] = None,
                   keep_decompositions: bool = False):
    """Run the layer-wise protocol. Returns (new params, stats[, decs]).

    ``calib_tokens`` (N, S) int32 (or (N, S, D) embeds for stub-frontend
    families). Hessians (X^T X) are collected only for SparseGPT.
    ``keep_decompositions`` additionally returns {(layer, path): dec}
    for core.packed_model.pack_model (kernel-served packed weights)."""
    stats: List[CompressStats] = []
    decs: Dict[Tuple[int, str], object] = {}
    x = jnp.asarray(calib_tokens)
    h = lm.embed_inputs(cfg, params, x)
    b, s = h.shape[0], h.shape[1]
    positions = positions_for(cfg, b, s)
    new_layers = jax.tree.map(lambda a: a, params["layers"])  # shallow copy

    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        acts = _layer_activations(cfg, params, lp, l, h, positions)
        hess: Dict[str, Array] = {}
        if collect_hessian or method == "sparsegpt":
            hess = _layer_hessians(cfg, lp, h, positions, acts)

        for pth in linear_paths(cfg):
            w = _get(lp, pth)
            if w is None:
                continue
            an = acts.get(pth)
            if w.ndim == 3:        # MoE experts (E, D, F): per-expert
                outs = []
                for e in range(w.shape[0]):
                    an_e = an[e] if (an is not None and an.ndim == 2) else an
                    o, _ = _compress_matrix(w[e], an_e, method, scfg,
                                            hess.get(pth))
                    outs.append(o)
                w_new = jnp.stack(outs)
            else:
                w_new, dec = _compress_matrix(w, an, method, scfg,
                                              hess.get(pth))
                if keep_decompositions and dec is not None:
                    decs[(l, pth)] = dec
            err_b = 0.0
            err_a = float(scores_lib.weighted_fro_error(
                w.T.astype(jnp.float32), w_new.T.astype(jnp.float32),
                None)) if w.ndim == 2 else 0.0
            stats.append(CompressStats(l, pth, err_b, err_a, scfg.cr))
            _set(lp, pth, w_new)

        # write back and propagate through the *compressed* layer
        new_layers = jax.tree.map(
            lambda buf, leaf: buf.at[l].set(leaf), new_layers, lp)
        params_l = dict(params)
        params_l["layers"] = new_layers
        h, _ = lm._layer_fwd(cfg, params_l, lp, jnp.asarray(l), h, positions)
        if progress:
            progress(f"layer {l + 1}/{cfg.n_layers} compressed")

    out = dict(params)
    out["layers"] = new_layers
    if keep_decompositions:
        return out, stats, decs
    return out, stats


def _layer_hessians(cfg, lp, h, positions, acts) -> Dict[str, Array]:
    """X^T X per linear (SparseGPT). Only 2-D dense-family paths."""
    out: Dict[str, Array] = {}
    hn = rms_norm(h, lp.get("attn_norm", lp.get("norm")), cfg.norm_eps)
    flat = hn.reshape(-1, hn.shape[-1]).astype(jnp.float32)
    hh = flat.T @ flat
    for pth in ("attn.wq", "attn.wk", "attn.wv"):
        out[pth] = hh
    if "mlp" in lp:
        h2 = h + _attention_context(cfg, lp["attn"], hn, positions) @ \
            lp["attn"]["wo"]
        hm = rms_norm(h2, lp["mlp_norm"], cfg.norm_eps)
        fm = hm.reshape(-1, hm.shape[-1]).astype(jnp.float32)
        hmm = fm.T @ fm
        out["mlp.w_gate"] = hmm
        out["mlp.w_up"] = hmm
        if cfg.act == "swiglu":
            mid = jax.nn.silu(hm @ lp["mlp"]["w_gate"]) * \
                (hm @ lp["mlp"]["w_up"])
        else:
            from repro.models.common import activation
            mid = activation(hm @ lp["mlp"]["w_up"],
                             "gelu" if cfg.act == "gelu" else "relu2")
        fmid = mid.reshape(-1, mid.shape[-1]).astype(jnp.float32)
        out["mlp.w_down"] = fmid.T @ fmid
        ctx = _attention_context(cfg, lp["attn"], hn, positions)
        fc = ctx.reshape(-1, ctx.shape[-1]).astype(jnp.float32)
        out["attn.wo"] = fc.T @ fc
    return out
