"""Layer-wise one-shot compression driver (the SparseGPT/Wanda protocol
the paper follows, §II-A1), with calibration statistics sourced from
**activation taps** and per-linear policy from a **CompressionPlan**.

  for each transformer layer, in order:
    (1) forward the calibration set — streamed in CalibrationSpec
        chunks — through the *already-compressed* prefix to the layer's
        inputs,
    (2) run the layer's REAL forward (``models.lm._layer_fwd``) under
        one ``models.common.tap_capture``: the ``linear()`` dispatch
        chokepoint reports every linear's exact input, reduced on the
        fly to ‖X‖₂ column norms and — only for linears whose resolved
        compressor declares ``"hessian" in needs`` — X^T X Hessians,
        accumulated across all calibration chunks,
    (3) resolve every linear through the plan (ordered glob rules over
        layer index + ``linear_paths`` names) and compress it with the
        matched registry compressor at the rule's config,
    (4) replace the weights and continue forward with the compressed
        layer's outputs (error propagation).

The tap protocol: modules name their linears (``linear(x, w,
tap="wq")``) under scope prefixes pushed by the layer assembly
("attn", "mlp", "moe", "moe.shared", "mamba", "shared"), so tap names
equal the ``linear_paths`` / ``shared_linear_paths`` entries below by
construction. One source of truth — attention, MoE dispatch (per-expert
stats see exactly the dispatched-token subsets, capacity drops
included), the Mamba-2 SSD scan, and the hybrid shared block are never
re-derived here. New scoring variants plug in through
``core.compressor.register`` + a plan rule, with zero edits to this
file.

Works on the model zoo's stacked-params layout: weights live as
``params["layers"][...]`` leaves with a leading L dim; we slice layer l,
compress its 2-D linears, and write them back. MoE experts are
compressed per-expert with expert-specific activation statistics. The
hybrid (zamba2) *shared* transformer block lives outside the stack
(``params["shared_attn"]``) and is compressed once, at its first firing
layer, from that invocation's ``shared.*`` taps — later invocations
then run (and propagate error through) the compressed shared weights.

Per the paper, embeddings and the LM head are excluded (§III-A4); norms,
biases and other 1-D leaves are untouched.

Stat collection and compression are **separable stages**:
``collect_model_stats`` runs ONE streaming calibration pass over the
uncompressed model and returns every layer's tapped statistics as a
``ModelTapStats``; ``compress_model(..., stats=...)`` then compresses
from those precollected statistics without any further forwards. The
sensitivity-driven budget allocator (``core.allocator``) is built on
this split — it probes per-layer CR→error frontiers from one pass and
hands both the concrete plan and the same stats back to the
compression stage, so allocate+compress costs exactly one calibration
pass. A plan with unallocated ``@auto`` rules routes through the
allocator automatically. (The classic single-call path keeps the
paper's error-propagation protocol: stats are tapped per layer from
the already-compressed prefix.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core import scores as scores_lib
from repro.core.compressor import LinearStats
from repro.core.slab import SLaBConfig
from repro.models import lm
from repro.models.common import ArchConfig, positions_for, tap_capture

Array = jax.Array


@dataclasses.dataclass
class CompressStats:
    layer: int
    name: str
    err_before: float   # ‖W diag(n)‖_F — the zero-approximation baseline
    err_after: float    # ‖(W - Ŵ) diag(n)‖_F with the same tapped norms
    cr: float           # measured compression ratio (requested if unknown)
    method: str = ""
    variant: str = ""   # packed-serving variant (core.packed_model
                        # variant_of); "" = no kernel-servable form
    cr_requested: float = 0.0   # the CR the resolved plan rule asked for
                                # (allocator decisions stay observable
                                # next to the measured value)


@dataclasses.dataclass
class ModelTapStats:
    """Whole-model tap statistics from ONE streaming calibration pass.

    Keys are ``(layer, path)`` with ``path`` a ``linear_paths`` /
    ``shared_linear_paths`` name (shared.* entries appear at the shared
    block's first firing layer, matching where the pipeline compresses
    them). ``n_forwards`` counts the ``models.lm._layer_fwd``
    invocations consumed — ``n_layers * n_chunks`` for one pass."""

    norms: Dict[Tuple[int, str], Array]
    hessians: Dict[Tuple[int, str], Array]
    n_forwards: int = 0


def _get(d: dict, path: str):
    cur = d
    for k in path.split("."):
        if k not in cur:
            return None
        cur = cur[k]
    return cur


def _set(d: dict, path: str, val):
    ks = path.split(".")
    cur = d
    for k in ks[:-1]:
        cur = cur[k]
    cur[ks[-1]] = val


def linear_paths(cfg: ArchConfig) -> List[str]:
    """Compressible 2-D linears inside one layer of this family."""
    if cfg.family in ("ssm", "hybrid"):
        return ["mamba.in_z", "mamba.in_x", "mamba.out"]
    paths = ["attn.wq", "attn.wk", "attn.wv", "attn.wo"]
    if cfg.family == "moe":
        paths += ["moe.w_gate", "moe.w_up", "moe.w_down"]  # (E, D, F) 3-D
        if cfg.shared_ff:
            paths += ["moe.shared.w_gate", "moe.shared.w_up",
                      "moe.shared.w_down"]
    elif cfg.act == "swiglu":
        paths += ["mlp.w_gate", "mlp.w_up", "mlp.w_down"]
    else:
        paths += ["mlp.w_up", "mlp.w_down"]
    return paths


def shared_linear_paths(cfg: ArchConfig) -> List[str]:
    """Hybrid (zamba2) shared-transformer-block linears. They live in
    ``params["shared_attn"]`` (outside the stacked layers) and tap as
    ``shared.*`` at layers where the block fires."""
    if cfg.family != "hybrid" or not cfg.attn_every:
        return []
    # the shared block is a plain attn+mlp transformer block: reuse the
    # dense-family path list under the "shared." tap scope
    return ["shared." + p for p in linear_paths(cfg.with_(family="dense"))]


def _capture_layer(cfg: ArchConfig, params: dict, lp: dict, idx: int,
                   chunks, positions: Sequence[Array],
                   paths: Sequence[str], hessian_names: set,
                   propagate: bool = False
                   ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """Run layer ``idx``'s real forward over every calibration chunk
    under ONE activation-tap capture: statistics accumulate across
    chunks (streaming multi-batch calibration). ``propagate`` writes
    each chunk's output back into ``chunks`` (the uncompressed-model
    stats pass, where the capture forward doubles as propagation)."""
    with tap_capture(hessian=bool(hessian_names),
                     hessian_names=set(hessian_names)) as tap:
        for i in range(len(chunks)):
            out, _ = lm._layer_fwd(cfg, params, lp, jnp.asarray(idx),
                                   chunks[i], positions[i])
            if propagate:
                chunks[i] = out
    acts: Dict[str, Array] = {}
    hess: Dict[str, Array] = {}
    for pth in paths:
        if not tap.has(pth):
            continue
        acts[pth] = tap.norms(pth)
        hz = tap.hessian(pth)
        if hz is not None:
            hess[pth] = hz
    return acts, hess


def layer_tap_stats(cfg: ArchConfig, params: dict, lp: dict, idx: int,
                    h: Array, positions: Array, hessian: bool = False,
                    hessian_names: Optional[set] = None
                    ) -> Tuple[Dict[str, Array], Dict[str, Array]]:
    """Single-batch convenience wrapper around ``_capture_layer``.

    Returns ``(act_norms, hessians)`` keyed by ``linear_paths`` /
    ``shared_linear_paths`` names: norms are (D_in,) — stacked (E, D_in)
    for MoE experts — and Hessians X^T X are (D_in, D_in) /
    (E, D_in, D_in); ``hessians`` is empty unless requested.
    """
    paths = linear_paths(cfg) + shared_linear_paths(cfg)
    names = set(paths) if hessian and hessian_names is None \
        else set(hessian_names or ())
    return _capture_layer(cfg, params, lp, idx, [h], [positions],
                          paths, names)


def collect_model_stats(cfg: ArchConfig, params: dict, calib,
                        plan=None,
                        hessian_names=None,
                        progress: Optional[Callable[[str], None]] = None
                        ) -> ModelTapStats:
    """ONE streaming calibration pass over the *uncompressed* model,
    tapping every layer's statistics (the allocator's sensitivity probe
    and the input to ``compress_model(stats=...)``).

    Each layer's capture forward doubles as the propagation to the next
    layer (weights are unchanged), so the whole collection costs exactly
    ``n_layers * n_chunks`` ``_layer_fwd`` calls — one pass. Hessians
    (X^T X) are accumulated for linears whose plan-resolved compressor
    declares ``"hessian" in needs`` (``@auto`` rules are probed at the
    base config); ``hessian_names`` overrides (a set of path names, or
    True for all)."""
    if plan is not None:
        plan = plan_lib.CompressionPlan.parse(plan)
    spec = (calib if isinstance(calib, plan_lib.CalibrationSpec)
            else plan_lib.CalibrationSpec(np.asarray(calib)))
    chunks: List[Array] = []
    positions: List[Array] = []
    for t in spec.batches():
        h = lm.embed_inputs(cfg, params, jnp.asarray(t))
        chunks.append(h)
        positions.append(positions_for(cfg, h.shape[0], h.shape[1]))

    norms: Dict[Tuple[int, str], Array] = {}
    hessians: Dict[Tuple[int, str], Array] = {}
    n_fwd = 0
    shared_pending = bool(cfg.family == "hybrid" and cfg.attn_every
                          and "shared_attn" in params)
    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        shared_now = (shared_pending
                      and l % cfg.attn_every == cfg.attn_every - 1)
        tap_paths = linear_paths(cfg) + (shared_linear_paths(cfg)
                                         if shared_now else [])
        if hessian_names is True:
            hnames = set(tap_paths)
        elif hessian_names is not None:
            hnames = set(hessian_names) & set(tap_paths)
        elif plan is not None:
            hnames = set()
            for p in tap_paths:
                r = plan.resolve(l, p, allow_auto=True)
                if r is not None and "hessian" in r.needs:
                    hnames.add(p)
        else:
            hnames = set()
        acts, hess = _capture_layer(cfg, params, lp, l, chunks, positions,
                                    tap_paths, hnames, propagate=True)
        n_fwd += len(chunks)
        for pth, an in acts.items():
            norms[(l, pth)] = an
        for pth, hz in hess.items():
            hessians[(l, pth)] = hz
        if shared_now:
            shared_pending = False
        if progress:
            progress(f"stats layer {l + 1}/{cfg.n_layers} tapped")
    return ModelTapStats(norms, hessians, n_fwd)


def _expert_hessians(hz: Optional[Array], n_exp: int, d_in: int
                     ) -> List[Optional[Array]]:
    """Per-expert Hessian slices. An expert that saw no calibration
    tokens (all-zero Gram) falls back to the identity, which reduces
    Hessian-aware methods to magnitude pruning instead of zeroing the
    expert. The zero-Gram check reads every expert's trace in a single
    device->host transfer."""
    if hz is None:
        return [None] * n_exp
    per = [hz[e] if hz.ndim == 3 else hz for e in range(n_exp)]
    tr = np.asarray(jnp.trace(hz, axis1=-2, axis2=-1)).reshape(-1)
    eye: Optional[Array] = None
    out: List[Optional[Array]] = []
    for e in range(n_exp):
        if tr[e if tr.size > 1 else 0] == 0.0:
            if eye is None:
                eye = jnp.eye(d_in, dtype=jnp.float32)
            out.append(eye)
        else:
            out.append(per[e])
    return out


def _weighted_errs(w: Array, w_new: Array, an: Optional[Array]
                   ) -> Tuple[float, float]:
    """(err_before, err_after): activation-weighted Frobenius error of
    the zero approximation (the pre-compression baseline — what a layer
    would lose if the linear were dropped entirely) and of the actual
    reconstruction, both under the same tapped norms."""
    wt = w.T.astype(jnp.float32)
    zero = jnp.zeros_like(wt)
    err_b = float(scores_lib.weighted_fro_error(wt, zero, an))
    err_a = float(scores_lib.weighted_fro_error(
        wt, w_new.T.astype(jnp.float32), an))
    return err_b, err_a


def _compress_leaf(layer: int, pth: str, w: Array, an: Optional[Array],
                   hz: Optional[Array],
                   r: plan_lib.ResolvedCompression):
    """Compress one parameter leaf (2-D linear or 3-D stacked experts).
    Returns (new weight, dec-or-None, CompressStats). Weights are stored
    (D_in, D_out) in our models — transposed to the paper's (D_out,
    D_in) convention for the compressor and back."""
    comp = r.compressor
    if w.ndim == 3:        # MoE experts (E, D, F): per-expert
        hz_e = _expert_hessians(hz, w.shape[0], w.shape[1])
        outs, crs, e_decs = [], [], []
        eb2 = ea2 = 0.0
        for e in range(w.shape[0]):
            an_e = an[e] if (an is not None and an.ndim == 2) else an
            cl = comp.compress(w[e].T.astype(jnp.float32),
                               LinearStats(norms=an_e, hessian=hz_e[e]))
            o = cl.dense.T.astype(w.dtype)
            outs.append(o)
            e_decs.append(cl.dec)
            if cl.cr is not None:
                crs.append(cl.cr)
            b_e, a_e = _weighted_errs(w[e], o, an_e)
            eb2 += b_e ** 2
            ea2 += a_e ** 2
        w_new = jnp.stack(outs)
        cr = float(np.mean(crs)) if crs else comp.scfg.cr
        # the per-expert decs travel as a tuple — pack_plan_decs routes
        # 3-D leaves to pack_expert_stack (expert-axis grouped kernels)
        dec = tuple(e_decs) if all(d is not None for d in e_decs) else None
        st = CompressStats(layer, pth, float(np.sqrt(eb2)),
                           float(np.sqrt(ea2)), cr, r.method,
                           "expert" if dec is not None else "",
                           cr_requested=float(r.scfg.cr))
        return w_new, dec, st
    cl = comp.compress(w.T.astype(jnp.float32),
                       LinearStats(norms=an, hessian=hz))
    w_new = cl.dense.T.astype(w.dtype)
    err_b, err_a = _weighted_errs(w, w_new, an)
    cr = cl.cr if cl.cr is not None else comp.scfg.cr
    variant = ""
    if cl.dec is not None:
        from repro.core.packed_model import variant_of
        variant = variant_of(cl.dec, r.scfg.pattern) or ""
    return w_new, cl.dec, CompressStats(layer, pth, err_b, err_a, cr,
                                        r.method, variant,
                                        cr_requested=float(r.scfg.cr))


def compress_model(cfg: ArchConfig, params: dict, calib,
                   method: str = "slab",
                   scfg: SLaBConfig = SLaBConfig(),
                   plan=None,
                   collect_hessian: bool = False,
                   progress: Optional[Callable[[str], None]] = None,
                   keep_decompositions: bool = False,
                   stats: Optional[ModelTapStats] = None):
    """Run the layer-wise protocol. Returns (new params, stats[, decs]).

    ``calib`` is an (N, S) int32 array (or (N, S, D) embeds for
    stub-frontend families), or a ``plan.CalibrationSpec`` to stream it
    in chunks (tap statistics accumulate across chunks). ``plan`` is
    anything ``CompressionPlan.parse`` accepts (a plan, inline DSL,
    JSON, a rule list); when None, ``method``/``scfg`` act as sugar for
    a single catch-all rule. Hessians (X^T X) are tapped only for
    linears whose resolved compressor declares ``"hessian" in needs``
    (or when ``collect_hessian`` forces it). ``keep_decompositions``
    additionally returns {(layer, path): dec} for
    core.packed_model.pack_plan_decs (kernel-served packed weights;
    pruning-only methods contribute sparse-only decompositions).

    ``stats`` (a ``ModelTapStats`` from ``collect_model_stats``)
    compresses from precollected statistics instead: no calibration
    forwards run at all (``calib`` may be None) and error propagation
    is skipped — the statistics describe the uncompressed model. A plan
    with ``@auto`` rules is first routed through the budget allocator
    (``core.allocator.allocate_plan``), which itself collects ``stats``
    when not given — the whole allocate+compress flow then costs
    exactly one calibration pass."""
    plan = (plan_lib.CompressionPlan.parse(plan, base=scfg)
            if plan is not None else plan_lib.plan_for_method(method, scfg))
    if plan.wants_allocation:
        from repro.core import allocator as allocator_lib
        allocation = allocator_lib.allocate_plan(
            cfg, params, calib, plan=plan, stats=stats, progress=progress)
        plan, stats = allocation.plan, allocation.stats
    precollected = stats is not None

    out_stats: List[CompressStats] = []
    decs: Dict[Tuple[int, str], object] = {}
    params = dict(params)   # top-level copy: shared_attn swapped in place
    chunks: List[Array] = []
    positions: List[Array] = []
    if not precollected:
        if calib is None:
            raise ValueError("compress_model needs calibration data "
                             "(or precollected stats=)")
        spec = (calib if isinstance(calib, plan_lib.CalibrationSpec)
                else plan_lib.CalibrationSpec(np.asarray(calib)))
        for t in spec.batches():
            h = lm.embed_inputs(cfg, params, jnp.asarray(t))
            chunks.append(h)
            positions.append(positions_for(cfg, h.shape[0], h.shape[1]))
    new_layers = jax.tree.map(lambda a: a, params["layers"])  # shallow copy
    shared_pending = bool(cfg.family == "hybrid" and cfg.attn_every
                          and "shared_attn" in params)

    for l in range(cfg.n_layers):
        lp = jax.tree.map(lambda a: a[l], params["layers"])
        paths = linear_paths(cfg)
        shared_now = (shared_pending
                      and l % cfg.attn_every == cfg.attn_every - 1)
        tap_paths = paths + (shared_linear_paths(cfg) if shared_now else [])
        resolved = {p: plan.resolve(l, p) for p in tap_paths}
        if precollected:
            acts = {p: stats.norms[(l, p)] for p in tap_paths
                    if (l, p) in stats.norms}
            hess = {p: stats.hessians[(l, p)] for p in tap_paths
                    if (l, p) in stats.hessians}
        else:
            hess_names = {p for p, r in resolved.items()
                          if r is not None and "hessian" in r.needs}
            if collect_hessian:
                hess_names = set(tap_paths)
            acts, hess = _capture_layer(cfg, params, lp, l, chunks,
                                        positions, tap_paths, hess_names)

        for pth in paths:
            r = resolved[pth]
            w = _get(lp, pth)
            if r is None or w is None:
                continue
            w_new, dec, st = _compress_leaf(l, pth, w, acts.get(pth),
                                            hess.get(pth), r)
            if keep_decompositions and dec is not None:
                decs[(l, pth)] = dec
            out_stats.append(st)
            _set(lp, pth, w_new)

        if shared_now:
            sp = jax.tree.map(lambda a: a, params["shared_attn"])
            changed = False
            for pth in shared_linear_paths(cfg):
                r = resolved[pth]
                sub = pth.split(".", 1)[1]       # strip the "shared." scope
                w = _get(sp, sub)
                if r is None or w is None:
                    continue
                w_new, dec, st = _compress_leaf(l, pth, w, acts.get(pth),
                                                hess.get(pth), r)
                if keep_decompositions and dec is not None:
                    # keyed at the firing layer under the "shared." path;
                    # pack_plan_decs packs these into params["shared_attn"]
                    decs[(l, pth)] = dec
                out_stats.append(st)
                _set(sp, sub, w_new)
                changed = True
            if changed:
                params["shared_attn"] = sp
            shared_pending = False   # one-shot: first firing layer only

        # write back and propagate through the *compressed* layer
        new_layers = jax.tree.map(
            lambda buf, leaf: buf.at[l].set(leaf), new_layers, lp)
        for i in range(len(chunks)):
            chunks[i], _ = lm._layer_fwd(cfg, params, lp, jnp.asarray(l),
                                         chunks[i], positions[i])
        if progress:
            progress(f"layer {l + 1}/{cfg.n_layers} compressed")

    out = dict(params)
    out["layers"] = new_layers
    if keep_decompositions:
        return out, out_stats, decs
    return out, out_stats
