"""Activation-aware pruning scores (Wanda-style), with streaming stats.

Paper Algorithm 1, line 3: ``S_X = diag(sqrt(X^T X))`` — the column-wise
L2 norm of the calibration activations feeding a linear layer. Scores are
``|Y| * S_X`` broadcast over output rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


class ActNormAccumulator:
    """Streaming accumulator for sqrt(sum_t x_t^2) over calibration batches.

    Activations arrive as (..., D_in); everything but the last dim is
    flattened into the token dim. fp32 accumulation.
    """

    def __init__(self, d_in: int):
        self.d_in = d_in
        self.sumsq = jnp.zeros((d_in,), dtype=jnp.float32)
        self.count = 0

    def update(self, x: Array) -> "ActNormAccumulator":
        x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        if x.shape[-1] != self.d_in:
            raise ValueError(f"expected D_in={self.d_in}, got {x.shape[-1]}")
        self.sumsq = self.sumsq + jnp.sum(x * x, axis=0)
        self.count += x.shape[0]
        return self

    def norms(self) -> Array:
        return jnp.sqrt(self.sumsq)


def act_col_norms(x: Array) -> Array:
    """One-shot column norms: diag(sqrt(X^T X)) for X (..., D_in)."""
    x = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    return jnp.sqrt(jnp.sum(x * x, axis=0))


def wanda_score(w: Array, act_norms: Array) -> Array:
    """S_ij = |W_ij| * ||X_j||_2 (Wanda); ``act_norms`` is (D_in,)."""
    return jnp.abs(w.astype(jnp.float32)) * act_norms[None, :].astype(jnp.float32)


def magnitude_score(w: Array) -> Array:
    return jnp.abs(w.astype(jnp.float32))


def weighted_fro_error(w: Array, w_hat: Array, act_norms: Array | None = None) -> Array:
    """||(W - W_hat) diag(n)||_F — the layer-output-aware reconstruction
    error (reduces to plain Frobenius when act_norms is None)."""
    d = (w - w_hat).astype(jnp.float32)
    if act_norms is not None:
        d = d * act_norms[None, :].astype(jnp.float32)
    return jnp.sqrt(jnp.sum(d * d))
