"""One-shot pruning baselines the paper compares against (Table I).

- magnitude: |W| scores.
- Wanda (Sun et al. 2023): |W| * ||X||_2 scores, no weight update.
- SparseGPT (Frantar & Alistarh 2023): Hessian-aware OBS pruning with
  column-blocked weight updates. Implemented faithfully (Cholesky of the
  damped inverse Hessian, per-block adaptive masks, error propagation);
  runs in fp32 numpy — compression is offline and one-shot.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scores as scores_lib
from repro.core import sparsity

Array = jax.Array


def magnitude_prune(
    w: Array, keep_frac: float,
    group: Tuple[int, int] = (1, 0), pattern: Optional[str] = None,
) -> Array:
    mask = sparsity.prune_mask(scores_lib.magnitude_score(w), keep_frac, group, pattern)
    return jnp.where(mask, w, 0)


def wanda_prune(
    w: Array, act_norms: Array, keep_frac: float,
    group: Tuple[int, int] = (1, 0), pattern: Optional[str] = None,
) -> Array:
    mask = sparsity.prune_mask(scores_lib.wanda_score(w, act_norms), keep_frac, group, pattern)
    return jnp.where(mask, w, 0)


def sparsegpt_prune(
    w: Array,
    hessian: Array,
    keep_frac: float,
    pattern: Optional[str] = None,
    blocksize: int = 128,
    percdamp: float = 0.01,
) -> Array:
    """SparseGPT on one layer. ``hessian`` = X^T X (D_in, D_in), fp32.

    Follows the reference implementation: damp the Hessian, take the
    Cholesky factor of its inverse (upper), then walk column blocks: pick
    the block's prune mask from the score w^2 / Hinv_diag^2 (unstructured:
    per-row top-k of the block; N:M: per m-group), zero the pruned weight,
    and distribute the quantization error onto the not-yet-visited columns.
    """
    wd = np.array(w, dtype=np.float32)
    d_out, d_in = wd.shape
    h = np.array(hessian, dtype=np.float64).copy()

    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    wd[:, dead] = 0.0
    damp = percdamp * float(np.mean(np.diag(h)))
    h[np.arange(d_in), np.arange(d_in)] += damp

    hinv = np.linalg.inv(h)
    # Upper Cholesky factor of H^{-1} (reference impl uses
    # cholesky(inv(H)) then cholesky_inverse + upper).
    hinv = np.linalg.cholesky(hinv[::-1, ::-1])[::-1, ::-1].T
    hinv = np.ascontiguousarray(hinv)

    nm = sparsity.parse_pattern(pattern) if pattern is not None else None
    prune_frac = 1.0 - keep_frac

    for i1 in range(0, d_in, blocksize):
        i2 = min(i1 + blocksize, d_in)
        cnt = i2 - i1
        w_blk = wd[:, i1:i2].copy()
        err_blk = np.zeros_like(w_blk)
        hinv_blk = hinv[i1:i2, i1:i2]
        diag = np.diag(hinv_blk).copy()
        diag[diag == 0] = 1e-8

        if nm is None:
            score = (w_blk ** 2) / (diag[None, :] ** 2)
            k_prune = int(round(prune_frac * cnt))
            if k_prune > 0:
                thresh_idx = np.argsort(score, axis=1)[:, :k_prune]
                mask_prune = np.zeros_like(w_blk, dtype=bool)
                np.put_along_axis(mask_prune, thresh_idx, True, axis=1)
            else:
                mask_prune = np.zeros_like(w_blk, dtype=bool)
        else:
            mask_prune = np.zeros_like(w_blk, dtype=bool)

        for j in range(cnt):
            col = w_blk[:, j]
            d = diag[j]
            if nm is not None and j % nm[1] == 0:
                # choose the (m - n) prune victims of this m-group
                m = nm[1]
                sub = (w_blk[:, j:j + m] ** 2) / (diag[None, j:j + m] ** 2)
                order = np.argsort(sub, axis=1)[:, : m - nm[0]]
                blk_mask = np.zeros_like(sub, dtype=bool)
                np.put_along_axis(blk_mask, order, True, axis=1)
                mask_prune[:, j:j + m] = blk_mask
            q = np.where(mask_prune[:, j], 0.0, col)
            e = (col - q) / d
            # propagate error within the remaining block columns
            w_blk[:, j:] -= np.outer(e, hinv_blk[j, j:])
            w_blk[:, j] = q
            err_blk[:, j] = e

        wd[:, i1:i2] = w_blk
        if i2 < d_in:
            wd[:, i2:] -= err_blk @ hinv[i1:i2, i2:]

    return jnp.asarray(wd, dtype=w.dtype)
