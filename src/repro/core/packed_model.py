"""Packed-weight model serving: every compressed linear lives in an
on-HBM packed format (N:M values+indices, row-padded ELL, bit-packed
W_B, rank-r u/v factors) and forwards through the fused Pallas kernels.

``PackedLinear`` is a **variant-tagged** registered pytree: the arrays
that exist depend on which decomposition terms the compressor produced,
and a static ``variant`` tag picks the kernel at dispatch time:

  variant          terms                       kernel
  ---------------  --------------------------  ---------------------------
  slab-nm          N:M W_S + W_B + rank-r UV   ops.slab_nm_matmul
  slab-ell         ELL W_S + W_B + rank-r UV   ops.slab_ell_matmul
  slab-dense       dense W_S + W_B + rank-r    ops.slab_matmul
  binlr            W_B + rank-r UV (no W_S)    ops.binlr
  lowrank-nm       N:M W_S + rank-r UV         ops.slab_nm_lr_matmul
  lowrank-ell      ELL W_S + rank-r UV         ops.ell_lr_matmul
  lowrank-dense    dense W_S + rank-r UV       ops.slab_lr_matmul
  lowrank          rank-r UV only              (x @ V) @ Uᵀ (XLA; already
                                               minimal bytes)
  sparse-nm        N:M W_S only                ops.nm_matmul
  sparse-ell       ELL W_S only                ops.ell_matmul
  sparse-dense     dense-masked W_S only       x @ W_Sᵀ (XLA; dense-masked
                                               bytes equal dense — the
                                               format tag still marks the
                                               linear as served-in-format)

Unstructured sparse parts are routed to the row-padded ELL format
(uint16 column ids, uint32 beyond 65535 columns; K_max = realized max
per-row nnz) whenever it wins on bytes — ``packing.ell_wins_bytes`` —
so unstructured SLaB / HASSLE-free / Wanda layers finally store fewer
HBM bytes than dense; the ``*-dense`` variants remain the fallback for
near-dense sparsity.

Static metadata (variant, m_pat, d_in, d_out, rank) rides in the pytree
aux data, so stacks of packed layers slice cleanly through ``lax.scan``
and ``jax.tree.map`` like any other parameter — and tree operations
refuse to mix variants (aux mismatch), which is exactly the stacking
invariant the packer enforces.

Heterogeneous paths — different variants/patterns/ranks across layers of
one path, or partial layer coverage — pack into a ``PackedStack``:
per-signature stacks keyed by the full packed signature (variant aux +
leaf shapes, so e.g. two ELL groups with different K_max never stack)
plus an optional stacked dense remainder. A PackedStack cannot slice
through ONE ``lax.scan`` (leaf shapes differ per layer), but the layer
axis always partitions into maximal contiguous runs with identical
per-path signatures — ``segment_runs`` — and each run scans: ``models.
lm`` drives one ``lax.scan`` per segment (`layer_slice_range` emits the
per-segment stacked leaves), so a mixed plan on an L-layer model traces
O(#segments) layer bodies instead of O(L).

CPU note: Mosaic only compiles on TPU; on CPU the kernels run in
interpret mode (numerics-exact, slow) — the packed path is exercised by
tests/examples at smoke scale and is the TPU serving configuration.
"""
from __future__ import annotations

import dataclasses
import types
import warnings
from typing import Dict, List, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import (ell_pack, ell_row_nnz_max, ell_wins_bytes,
                                pack_nm, pack_sign_bits)
from repro.core.slab import SLaBDecomposition
from repro.models.common import is_axes_leaf, tap_record

Array = jax.Array

PACKED_VARIANTS = ("slab-nm", "slab-ell", "slab-dense", "binlr",
                   "lowrank-nm", "lowrank-ell", "lowrank-dense", "lowrank",
                   "sparse-nm", "sparse-ell", "sparse-dense")

# Rank threshold for sharding the low-rank u factor on "model": below
# this the (D_out, r) plane is a few KB and replicating it beats paying
# a collective for the rank-r correction; at/above it u row-shards with
# the other d_out planes. v (D_in, r) always replicates — it contracts
# against the (replicated) input features.
LR_SHARD_RANK = 8


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """One compressed linear, model-orientation (computes x @ Wᵀ for the
    paper's (D_out, D_in) W — i.e. a drop-in for x @ w, w (D_in, D_out)).

    Array fields are pytree children (absent terms are None); the
    variant tag and shape metadata are static aux data, preserved by
    stacking/slicing and checked for equality by tree operations.

    sparse_vals : (D_out, D_in) dense-masked W_S, or (D_out, D_in/m, n)
                  N:M values, or (D_out, K_max) ELL values, or None.
    sparse_idx  : (D_out, D_in/m, n) int8 N:M positions, or
                  (D_out, K_max) uint16 ELL column ids, or None.
    b_packed    : (D_out, D_in/32) uint32 sign bits, or None.
    u, v        : (D_out, r) / (D_in, r) low-rank factors, or None.
    """

    sparse_vals: Optional[Array]
    sparse_idx: Optional[Array]
    b_packed: Optional[Array]
    u: Optional[Array]
    v: Optional[Array]
    variant: str = "slab-dense"
    m_pat: int = 0            # N:M group size m (0 = not N:M)
    d_in: int = 0
    d_out: int = 0
    rank: int = 0

    def tree_flatten(self):
        return ((self.sparse_vals, self.sparse_idx, self.b_packed,
                 self.u, self.v),
                (self.variant, self.m_pat, self.d_in, self.d_out,
                 self.rank))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedStack:
    """Signature-grouped packed stacks for one linear path across the
    layer dim.

    ``groups[g]`` is a PackedLinear stacked over ``members[g]`` (layer
    ids, ascending); ``dense`` is the original stacked weight restricted
    to ``dense_members`` — layers the plan left dense (partial
    coverage). Membership is static aux data so ``at_layer`` /
    ``segment`` resolve at trace time; the model scans contiguous
    same-signature layer runs of one of these (``segment_runs``)."""

    groups: Tuple[PackedLinear, ...]
    dense: Optional[Array]
    members: Tuple[Tuple[int, ...], ...]
    dense_members: Tuple[int, ...]
    n_layers: int

    def tree_flatten(self):
        return ((self.groups, self.dense),
                (self.members, self.dense_members, self.n_layers))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def owner_group(self, l: int) -> int:
        """Index of the group holding layer ``l`` (-1 = dense remainder)."""
        for gi, mem in enumerate(self.members):
            if l in mem:
                return gi
        if l in self.dense_members:
            return -1
        raise KeyError(f"layer {l} not held by this PackedStack")

    def at_layer(self, l: int):
        """The layer-``l`` leaf: a sliced PackedLinear or a dense 2-D
        weight (in model (D_in, D_out) orientation)."""
        leaf = self.segment(l, l + 1)
        return jax.tree.map(lambda a: a[0], leaf)

    def _seg_cache(self) -> dict:
        """Per-instance memo of pre-sliced segment leaves. Lives outside
        the pytree (plain attribute on the frozen dataclass), so slicing
        each run happens ONCE per stack instance instead of at every
        trace — the scan body then carries no layer-axis slicing at all.
        Tracer leaves are never cached (a stack passed as a jit argument
        would otherwise leak its tracers past the trace)."""
        c = self.__dict__.get("_segcache")
        if c is None:
            c = {}
            object.__setattr__(self, "_segcache", c)
        return c

    def segment(self, lo: int, hi: int):
        """The stacked leaf for the contiguous layer run [lo, hi): a
        (hi-lo)-stacked PackedLinear or dense weight stack. The run must
        lie inside ONE group (or the dense remainder) — guaranteed for
        runs produced by ``segment_runs``; membership tuples are sorted,
        so in-group runs are contiguous slices of the stacked arrays.
        A run covering an entire group returns that group's stack
        unsliced (identity — no copy), and concrete slices are memoized
        per instance (``_seg_cache``)."""
        cache = self._seg_cache()
        out = cache.get((lo, hi))
        if out is not None:
            return out
        gi = self.owner_group(lo)
        if gi < 0:
            i = self.dense_members.index(lo)
            if self.dense_members[i:i + hi - lo] != tuple(range(lo, hi)):
                raise ValueError(f"layers [{lo},{hi}) straddle groups")
            out = (self.dense if len(self.dense_members) == hi - lo
                   else self.dense[i:i + hi - lo])
        else:
            mem = self.members[gi]
            i = mem.index(lo)
            if mem[i:i + hi - lo] != tuple(range(lo, hi)):
                raise ValueError(f"layers [{lo},{hi}) straddle groups")
            out = (self.groups[gi] if len(mem) == hi - lo
                   else jax.tree.map(lambda a: a[i:i + hi - lo],
                                     self.groups[gi]))
        if not any(isinstance(a, jax.core.Tracer)
                   for a in jax.tree.leaves(out)):
            cache[(lo, hi)] = out
        return out

    def variant_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for grp, mem in zip(self.groups, self.members):
            out[grp.variant] = out.get(grp.variant, 0) + len(mem)
        return out


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ExpertPackedStack:
    """Signature-grouped packed stacks for one 3-D MoE leaf across the
    EXPERT dim — the expert-axis analogue of ``PackedStack``'s layer
    grouping.

    ``groups[g]`` is a PackedLinear whose every plane carries a leading
    expert dim over ``members[g]`` (expert ids, ascending). Experts
    group by full packed signature; for ELL variants the per-expert
    realized K_max is first quantized into buckets
    (``pack_expert_stack``) and each bucket pads to ITS realized max —
    ragged experts never pad to the global max. ``dense`` holds the
    original model-orientation ``(E_d, D_in, D_out)`` slices for
    experts with no packable terms. One grouped-kernel launch serves a
    whole bucket (``expert_matmul``), with the expert index leading the
    Pallas grid (kernels.grouped).

    Layer stacking is structural: a stacked ExpertPackedStack simply
    carries an extra leading layer dim on every child (groups' planes
    ``(L, E_g, ...)``, dense ``(L, E_d, D_in, D_out)``), so it slices
    through ``lax.scan`` / ``layer_slice_range`` like any packed leaf
    and nests as a PackedStack group when per-layer bucketings differ.
    """

    groups: Tuple[PackedLinear, ...]
    dense: Optional[Array]
    members: Tuple[Tuple[int, ...], ...]
    dense_members: Tuple[int, ...]
    n_experts: int

    def tree_flatten(self):
        return ((self.groups, self.dense),
                (self.members, self.dense_members, self.n_experts))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def variant_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for grp, mem in zip(self.groups, self.members):
            out[grp.variant] = out.get(grp.variant, 0) + len(mem)
        return out


def _is_packed_leaf(x) -> bool:
    return isinstance(x, (PackedLinear, PackedStack, ExpertPackedStack))


def has_hetero(tree) -> bool:
    """True if any leaf is a PackedStack (forces the segmented layer
    loop; homogeneous stacked PackedLinears scan as one segment)."""
    return any(isinstance(l, PackedStack)
               for l in jax.tree.leaves(tree, is_leaf=_is_packed_leaf))


def layer_slice(tree, l: int):
    """Slice a stacked layers tree at layer ``l``, resolving PackedStack
    leaves to their layer-``l`` representation."""
    def f(x):
        if isinstance(x, PackedStack):
            return x.at_layer(l)
        if isinstance(x, (PackedLinear, ExpertPackedStack)):
            return jax.tree.map(lambda a: a[l], x)
        return x[l]
    return jax.tree.map(f, tree, is_leaf=_is_packed_leaf)


# ------------------------------------------------------------------
# Contiguous-segment scan groups
# ------------------------------------------------------------------

def segment_runs(tree, n_layers: int) -> Tuple[Tuple[int, int], ...]:
    """Partition the layer axis into maximal contiguous runs [lo, hi)
    with identical packed signatures: within a run, every PackedStack
    leaf stays inside one of its groups (or its dense remainder), so
    ``layer_slice_range`` yields per-segment stacked leaves with
    layer-invariant structure and one ``lax.scan`` drives the whole
    run. A fully homogeneous tree is the single run ((0, L),)."""
    stacks = [l for l in jax.tree.leaves(tree, is_leaf=_is_packed_leaf)
              if isinstance(l, PackedStack)]
    owners = [[s.owner_group(l) for s in stacks] for l in range(n_layers)]
    runs: List[Tuple[int, int]] = []
    lo = 0
    for l in range(1, n_layers):
        if owners[l] != owners[l - 1]:
            runs.append((lo, l))
            lo = l
    runs.append((lo, n_layers))
    return tuple(runs)


def layer_slice_range(tree, lo: int, hi: int):
    """Restrict a stacked layers tree to the contiguous run [lo, hi),
    resolving PackedStack leaves to their per-segment stacked form.
    Every leaf keeps a leading layer dim of hi-lo, so the result scans.
    A run spanning a leaf's full layer axis passes it through unsliced
    (identity — the homogeneous one-segment path copies nothing)."""
    def f(x):
        if isinstance(x, PackedStack):
            return x.segment(lo, hi)
        if isinstance(x, (PackedLinear, ExpertPackedStack)):
            leaves = jax.tree.leaves(x)
            if lo == 0 and leaves and leaves[0].shape[0] == hi:
                return x
            return jax.tree.map(lambda a: a[lo:hi], x)
        if lo == 0 and x.shape[0] == hi:
            return x
        return x[lo:hi]
    return jax.tree.map(f, tree, is_leaf=_is_packed_leaf)


# ------------------------------------------------------------------
# Logical axes for the sharding planner (tensor-parallel serving)
# ------------------------------------------------------------------

def _stack_depth(pl: PackedLinear) -> int:
    """0 for a per-layer PackedLinear, 1 for a layer-stacked one."""
    if pl.sparse_vals is not None:
        base = 3 if pl.variant.endswith("-nm") else 2
        return pl.sparse_vals.ndim - base
    a = pl.u if pl.u is not None else pl.b_packed
    return a.ndim - 2


def packed_linear_axes(pl: PackedLinear, stacked: bool = False,
                       lr_shard_rank: int = LR_SHARD_RANK,
                       _lead: Optional[Tuple[str, ...]] = None
                       ) -> PackedLinear:
    """The logical-axes tree of one packed linear: a PackedLinear with
    IDENTICAL static aux whose children are axes tuples, so it pairs
    structurally against the array tree in ``Planner.tree_specs`` /
    ``jax.tree.map``. Every stored plane except ``v`` leads with d_out
    — N:M values/indices ``(D_out, D_in/m, n)``, ELL planes ``(D_out,
    K_max)``, dense-masked values ``(D_out, D_in)``, sign bits
    ``(D_out, D_in/32)``, ``u (D_out, r)`` — so tensor parallelism is
    uniform row sharding on ``"packed_out"`` (-> "model"). N:M groups
    and ELL rows run along d_in and are never split by a d_out shard;
    a d_out that doesn't divide the mesh replicates via the planner's
    standard divisibility fallback (degraded-but-correct). ``u`` only
    shards at rank >= ``lr_shard_rank``; ``v (D_in, r)`` always
    replicates (it contracts the replicated input features).
    ``_lead`` overrides the leading logical axes — the expert-stacked
    variant passes ``(..., "experts")`` so expert planes prefer EP
    ("experts" -> "model") and fall back to "packed_out" row sharding
    via the planner's one-axis-per-spec rule when the bucket size
    doesn't divide the mesh."""
    lead = _lead if _lead is not None else (("layers",) if stacked else ())

    def ax(a, row_sharded=True):
        if a is None:
            return None
        nd = a.ndim - len(lead)
        first = "packed_out" if row_sharded else None
        return lead + (first,) + (None,) * (nd - 1)

    return PackedLinear(
        ax(pl.sparse_vals), ax(pl.sparse_idx), ax(pl.b_packed),
        ax(pl.u, pl.rank >= lr_shard_rank), ax(pl.v, False),
        variant=pl.variant, m_pat=pl.m_pat, d_in=pl.d_in,
        d_out=pl.d_out, rank=pl.rank)


def _expert_stack_depth(eps: ExpertPackedStack) -> int:
    """0 for a per-layer ExpertPackedStack, 1 for a layer-stacked one
    (every plane then carries layer + expert leading dims)."""
    if eps.groups:
        return _stack_depth(eps.groups[0]) - 1
    return eps.dense.ndim - 3


def expert_stack_axes(eps: ExpertPackedStack, stacked: bool = False,
                      lr_shard_rank: int = LR_SHARD_RANK
                      ) -> ExpertPackedStack:
    """Axes tree of an ExpertPackedStack: each group's planes lead with
    the expert dim ("experts" -> "model", expert parallelism) ahead of
    the usual per-plane "packed_out" rows; the dense remainder is
    model-orientation ``(E_d, D_in, D_out)``. When the bucket size
    doesn't divide the mesh, the planner's divisibility fallback drops
    "experts" and the spec row-shards on "packed_out" instead —
    degraded-but-correct, mirroring the dense-path fallbacks."""
    lead = (("layers",) if stacked else ()) + ("experts",)
    groups = tuple(packed_linear_axes(g, lr_shard_rank=lr_shard_rank,
                                      _lead=lead)
                   for g in eps.groups)
    dense = (lead + (None, "packed_out")
             if eps.dense is not None else None)
    return ExpertPackedStack(groups, dense, eps.members,
                             eps.dense_members, eps.n_experts)


def packed_stack_axes(ps: PackedStack,
                      lr_shard_rank: int = LR_SHARD_RANK) -> PackedStack:
    """Axes tree of a PackedStack: per-group stacked PackedLinear (or
    ExpertPackedStack) axes plus ``("layers", None, "packed_out")`` for
    the dense remainder (model-orientation ``(run, D_in, D_out)`` —
    output dim last; MoE remainders add an "experts" dim)."""
    groups = tuple(
        expert_stack_axes(g, stacked=True, lr_shard_rank=lr_shard_rank)
        if isinstance(g, ExpertPackedStack)
        else packed_linear_axes(g, stacked=True,
                                lr_shard_rank=lr_shard_rank)
        for g in ps.groups)
    dense = None
    if ps.dense is not None:
        dense = (("layers", "experts", None, "packed_out")
                 if ps.dense.ndim == 4 else ("layers", None, "packed_out"))
    return PackedStack(groups, dense, ps.members, ps.dense_members,
                       ps.n_layers)


def packed_axes(leaf, lr_shard_rank: int = LR_SHARD_RANK):
    """Axes tree for any packed leaf (PackedLinear, PackedStack, or
    ExpertPackedStack)."""
    if isinstance(leaf, PackedStack):
        return packed_stack_axes(leaf, lr_shard_rank)
    if isinstance(leaf, ExpertPackedStack):
        return expert_stack_axes(leaf, stacked=_expert_stack_depth(leaf) > 0,
                                 lr_shard_rank=lr_shard_rank)
    return packed_linear_axes(leaf, stacked=_stack_depth(leaf) > 0,
                              lr_shard_rank=lr_shard_rank)


def merge_packed_axes(axes_tree, params_tree):
    """Substitute per-variant packed axes subtrees into a dense logical-
    axes tree (``lm.param_axes``) wherever ``params_tree`` holds a
    packed leaf. The result feeds ``Planner.tree_specs`` /
    ``tree_shardings`` unchanged: an axes-PackedLinear node pairs
    against the array PackedLinear structurally (same aux), and its
    tuple children stop descent exactly like plain dense axes leaves."""
    def f(ax, leaf):
        if _is_packed_leaf(leaf):
            return packed_axes(leaf)
        return ax
    return jax.tree.map(f, axes_tree, params_tree, is_leaf=is_axes_leaf)


# ------------------------------------------------------------------
# Variant classification + per-linear packing
# ------------------------------------------------------------------

def _dec_rank(dec: SLaBDecomposition) -> int:
    if dec.u is None or not dec.u.size:
        return 0
    return dec.u.shape[1] if dec.u.ndim == 2 else 1


def _unstructured_kind(w_s: Array, itemsize: Optional[int] = None,
                       k_max: Optional[int] = None) -> str:
    """"ell" when row-padded ELL beats the dense bytes of this sparse
    part (uint32 ids absorb D_in beyond uint16), else "dense".
    ``itemsize`` is the SERVING value width (defaults to the dec's own
    dtype; the packer passes its pack dtype — a bf16 serve halves the
    dense bytes and tightens the ELL threshold to K_max < D_in/2).
    ``k_max`` skips the row-nnz device sync when the caller already
    paid it — otherwise pack/classification time only."""
    d_in = w_s.shape[1]
    itemsize = w_s.dtype.itemsize if itemsize is None else itemsize
    if k_max is None:
        k_max = ell_row_nnz_max(w_s)
    if ell_wins_bytes(k_max, d_in, itemsize):
        return "ell"
    return "dense"


def variant_of(dec: SLaBDecomposition, pattern: Optional[str],
               itemsize: Optional[int] = None,
               k_max: Optional[int] = None,
               has_s: Optional[bool] = None) -> Optional[str]:
    """Classify one decomposition into its packed-serving variant (None
    = not representable; stays dense). The binary term only counts when
    a low-rank factor exists — W_L ⊙ W_B with empty W_L is identically
    zero (see core.slab.low_rank_times_binary), so a lone W_B carries no
    signal and the sparse part serves alone. ``has_s`` (is the sparse
    part non-zero) skips that device sync when the caller batched it —
    ``pack_expert_stack`` classifies every expert from ONE fused
    reduction."""
    if dec.w_s is None or dec.w_s.ndim != 2:
        return None
    rank = _dec_rank(dec)
    has_b = (dec.w_b is not None and dec.w_b.size > 0 and rank > 0)
    if not has_b and rank == 0:
        # pruning-only dec: the sparse part is the only term — route it
        # to ELL when that wins on bytes (an all-zero W_S packs as a
        # width-1 ELL serving zeros, same as its dense equivalent)
        kind = ("nm" if pattern
                else _unstructured_kind(dec.w_s, itemsize, k_max))
        return f"sparse-{kind}"
    if has_s is None:
        has_s = bool(dec.w_s.size) and bool(jnp.any(dec.w_s != 0))
    kind = (("nm" if pattern
             else _unstructured_kind(dec.w_s, itemsize, k_max))
            if has_s else None)
    if has_b:
        return f"slab-{kind}" if kind else "binlr"
    if rank > 0:
        return f"lowrank-{kind}" if kind else "lowrank"
    return f"sparse-{kind}" if kind else None


def pack_linear(dec: SLaBDecomposition, pattern: Optional[str],
                dtype=jnp.float32,
                variant: Optional[str] = None,
                ell_nnz: Optional[int] = None) -> PackedLinear:
    """Pack one decomposition into its variant's storage format.
    ``ell_nnz`` overrides the ELL pad width K_max (callers that already
    synced the row-nnz reduction, or that stack several layers at one
    shared width, pass it to skip the recompute)."""
    d_out, d_in = dec.w_s.shape
    if variant is None:
        variant = variant_of(dec, pattern,
                             itemsize=jnp.dtype(dtype).itemsize,
                             k_max=ell_nnz)
    if variant is None:
        raise ValueError("decomposition has no packable terms")
    rank = _dec_rank(dec)
    u = v = bp = vals = idx = None
    m_pat = 0
    if rank:
        u = (dec.u if dec.u.ndim == 2 else dec.u[:, None]).astype(dtype)
        v = (dec.v if dec.v.ndim == 2 else dec.v[:, None]).astype(dtype)
    if variant.startswith("slab-") or variant == "binlr":
        bp = pack_sign_bits(dec.w_b)
    if variant.endswith("-nm"):
        n, m_pat = map(int, pattern.split(":"))
        # strict: a rule pattern that disagrees with the compressor's
        # actual output must fail loudly, not drop values
        nm = pack_nm(dec.w_s.astype(dtype), n, m_pat, strict=True)
        vals, idx = nm.values, nm.indices
    elif variant.endswith("-ell"):
        ep = ell_pack(dec.w_s.astype(dtype), nnz=ell_nnz)
        vals, idx = ep.values, ep.indices
    elif variant.endswith("-dense") or variant.startswith("sparse"):
        vals = dec.w_s.astype(dtype)
    return PackedLinear(vals, idx, bp, u, v, variant=variant, m_pat=m_pat,
                        d_in=d_in, d_out=d_out, rank=rank)


def _pick_block(dim: int, cap: int, mult: int = 1) -> int:
    """Largest block ≤ cap that divides ``dim`` and is a multiple of
    ``mult`` — collapses the grid to one step whenever the axis fits
    (the dominant cost at decode/smoke shapes is per-grid-step, not
    per-element). Falls back to the full axis (single block)."""
    if dim <= cap:
        return dim
    for b in range(cap, 0, -1):
        if dim % b == 0 and b % mult == 0:
            return b
    return dim


def _local_dim(dim: int) -> int:
    """The per-shard extent of a "packed_out" dim under the ambient
    mesh: block-size picking must see what one device actually holds,
    or the kernel grid can't partition along the sharded rows (a block
    spanning two shards forces GSPMD to gather the whole plane). Any
    divisor of dim // n_model also divides dim, so the grid stays valid
    for the global shape; without a mesh (or a non-dividing d_out,
    which replicates) this is the identity and block choices are
    byte-identical to the single-device path."""
    from repro.runtime.meshctx import current_mesh
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return dim
    n = mesh.shape["model"]
    return dim // n if (n > 1 and dim % n == 0) else dim


def packed_matmul(x: Array, w: PackedLinear,
                  interpret: Optional[bool] = None) -> Array:
    """x (..., D_in) @ Wᵀ through the variant's fused kernel."""
    from repro.kernels import ops
    var = w.variant
    if var.endswith("-ell"):
        kw = dict(bm=128, bn=_pick_block(_local_dim(w.d_out), 256),
                  interpret=interpret)
        if var == "sparse-ell":
            y = ops.ell_matmul(x, w.sparse_vals, w.sparse_idx, **kw)
        elif var == "lowrank-ell":
            y = ops.ell_lr_matmul(x, w.sparse_vals, w.sparse_idx,
                                  w.u, w.v, **kw)
        else:
            y = ops.slab_ell_matmul(x, w.sparse_vals, w.sparse_idx,
                                    w.b_packed, w.u, w.v, **kw)
        return y.astype(x.dtype)
    mult = (w.m_pat or 1) * (32 if (w.b_packed is not None) else 1)
    kw = dict(bm=128, bn=_pick_block(_local_dim(w.d_out), 256),
              bk=_pick_block(w.d_in, 1024, mult), interpret=interpret)
    if var == "slab-nm":
        y = ops.slab_nm_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat,
                               w.b_packed, w.u, w.v, **kw)
    elif var == "slab-dense":
        y = ops.slab_matmul(x, w.sparse_vals.astype(x.dtype), w.b_packed,
                            w.u, w.v, **kw)
    elif var == "binlr":
        y = ops.binlr(x, w.b_packed, w.u, w.v, **kw)
    elif var == "lowrank-nm":
        y = ops.slab_nm_lr_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat,
                                  w.u, w.v, **kw)
    elif var == "lowrank-dense":
        y = ops.slab_lr_matmul(x, w.sparse_vals.astype(x.dtype),
                               w.u, w.v, **kw)
    elif var == "lowrank":
        # two skinny XLA matmuls: r(D_in + D_out) weights per token —
        # already the minimal-byte form, nothing left to fuse
        y = (x.astype(jnp.float32) @ w.v.astype(jnp.float32)) \
            @ w.u.astype(jnp.float32).T
    elif var == "sparse-nm":
        y = ops.nm_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat, **kw)
    elif var == "sparse-dense":
        # dense-masked bytes equal dense bytes: a plain dot IS the
        # optimal serve; the tag records the linear as served-in-format
        y = x @ w.sparse_vals.astype(x.dtype).T
    else:
        raise ValueError(f"unknown packed variant {var!r}")
    return y.astype(x.dtype)


def packed_matmul_grouped(x: Array, w: PackedLinear,
                          interpret: Optional[bool] = None) -> Array:
    """x (E, M, D_in) against an expert-stacked PackedLinear (every
    plane leads with E) -> (E, M, D_out), one grouped-kernel launch
    with the expert index leading the Pallas grid (kernels.grouped)."""
    from repro.kernels import ops
    var = w.variant
    if var.endswith("-ell"):
        kw = dict(bm=128, bn=_pick_block(_local_dim(w.d_out), 256),
                  interpret=interpret)
        if var == "sparse-ell":
            y = ops.ell_matmul_g(x, w.sparse_vals, w.sparse_idx, **kw)
        elif var == "lowrank-ell":
            y = ops.ell_lr_matmul_g(x, w.sparse_vals, w.sparse_idx,
                                    w.u, w.v, **kw)
        else:
            y = ops.slab_ell_matmul_g(x, w.sparse_vals, w.sparse_idx,
                                      w.b_packed, w.u, w.v, **kw)
        return y.astype(x.dtype)
    mult = (w.m_pat or 1) * (32 if (w.b_packed is not None) else 1)
    kw = dict(bm=128, bn=_pick_block(_local_dim(w.d_out), 256),
              bk=_pick_block(w.d_in, 1024, mult), interpret=interpret)
    if var == "slab-nm":
        y = ops.slab_nm_matmul_g(x, w.sparse_vals, w.sparse_idx, w.m_pat,
                                 w.b_packed, w.u, w.v, **kw)
    elif var == "slab-dense":
        y = ops.slab_matmul_g(x, w.sparse_vals.astype(x.dtype),
                              w.b_packed, w.u, w.v, **kw)
    elif var == "binlr":
        y = ops.binlr_g(x, w.b_packed, w.u, w.v, **kw)
    elif var == "lowrank-nm":
        y = ops.slab_nm_lr_matmul_g(x, w.sparse_vals, w.sparse_idx,
                                    w.m_pat, w.u, w.v, **kw)
    elif var == "lowrank-dense":
        y = ops.slab_lr_matmul_g(x, w.sparse_vals.astype(x.dtype),
                                 w.u, w.v, **kw)
    elif var == "lowrank":
        # two skinny batched XLA matmuls — already minimal bytes
        y = jnp.einsum("emk,ekr->emr", x.astype(jnp.float32),
                       w.v.astype(jnp.float32))
        y = jnp.einsum("emr,enr->emn", y, w.u.astype(jnp.float32))
    elif var == "sparse-nm":
        y = ops.nm_matmul_g(x, w.sparse_vals, w.sparse_idx, w.m_pat, **kw)
    elif var == "sparse-dense":
        y = jnp.einsum("emk,enk->emn", x, w.sparse_vals.astype(x.dtype))
    else:
        raise ValueError(f"unknown packed variant {var!r}")
    return y.astype(x.dtype)


def expert_matmul(x: Array, w: ExpertPackedStack,
                  interpret: Optional[bool] = None) -> Array:
    """Per-expert packed linear: x (E, M, D_in) -> (E, M, D_out).

    One grouped-kernel launch per expert BUCKET: experts of a bucket
    share packed shapes (same variant / rank / ELL pad width), so each
    launch streams a contiguous (E_g, ...) plane stack. Expert ids are
    static aux, so the bucket gathers/reorder resolve to constant-index
    gathers at trace time; the common all-in-one-bucket case skips them
    entirely."""
    n = w.n_experts
    if (len(w.groups) == 1 and not w.dense_members
            and w.members[0] == tuple(range(n))):
        return packed_matmul_grouped(x, w.groups[0], interpret)
    parts: List[Array] = []
    order: List[int] = []
    for mem, grp in zip(w.members, w.groups):
        xg = jnp.take(x, jnp.asarray(mem), axis=0)
        parts.append(packed_matmul_grouped(xg, grp, interpret))
        order.extend(mem)
    if w.dense is not None:
        xd = jnp.take(x, jnp.asarray(w.dense_members), axis=0)
        parts.append(jnp.einsum("emk,ekn->emn", xd,
                                w.dense.astype(x.dtype)).astype(x.dtype))
        order.extend(w.dense_members)
    y = jnp.concatenate(parts, axis=0)
    inv = [0] * n
    for pos, eid in enumerate(order):
        inv[eid] = pos
    return jnp.take(y, jnp.asarray(inv), axis=0)


# q/k/v projections: output is a flat head*dh dim that the attention
# layers immediately reshape per head — never constrain it flat.
_FLAT_HEAD_TAPS = frozenset(("wq", "wk", "wv"))


def linear(x: Array, w, tap: Optional[str] = None) -> Array:
    """Dispatch point used by the model layers: dense `x @ w` or the
    packed fused kernel. ``tap`` names this linear for activation
    capture (models.common.tap_capture): when a capture is active the
    exact input ``x`` is reported under the current tap scope before
    the matmul runs; otherwise it's a no-op."""
    if tap is not None:
        tap_record(tap, x)
    if isinstance(w, PackedLinear):
        from repro.runtime.meshctx import hint
        y = packed_matmul(x, w)
        if tap in _FLAT_HEAD_TAPS:
            # q/k/v leave here flat (B, S, heads*dh) and are
            # immediately re-laid-out per head; pinning the flat dim
            # fights the head layout across the decode cache update
            # and miscompiles under SPMD with the interpret-mode
            # kernel (the mesh parity tests in tests/test_distributed
            # caught real wrong logits) — leave them to propagation.
            return y
        # the packed-TP layout: every stored plane row-shards on d_out,
        # so each device owns whole output rows and the result is
        # "model"-sharded on its feature dim — one constraint per
        # packed linear, mirroring the dense TP layout. hint() no-ops
        # without a mesh and falls back when d_out doesn't divide
        # (replicated degraded path).
        return hint(y, *(None,) * (y.ndim - 1), "model")
    return x @ w


# ------------------------------------------------------------------
# Whole-model packing
# ------------------------------------------------------------------

class Segment(NamedTuple):
    """One contiguous same-signature layer run of a packed model."""
    lo: int
    hi: int                            # exclusive
    sig: Tuple[Tuple[str, str], ...]   # (path, descriptor) per packed path


class PackReport(NamedTuple):
    """What pack_plan_decs did: per-variant packed-linear counts, the
    packed paths, the (layer, path) decs left on the dense path, the
    contiguous scan segments, and per-variant packed-vs-dense bytes."""
    n_packed: int
    by_variant: Dict[str, int]
    paths: List[str]
    fallback: List[Tuple[int, str]]
    segments: Tuple[Segment, ...] = ()
    bytes_by_variant: Mapping[str, Tuple[float, float]] = \
        types.MappingProxyType({})   # immutable: defaults never alias a
                                     # mutable dict across instances


def _stack_group(pls: List[PackedLinear]) -> PackedLinear:
    if len(pls) == 1:
        return jax.tree.map(lambda a: a[None], pls[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pls)


def _pack_signature(pl: PackedLinear) -> Tuple:
    """Full stacking key: static aux + per-leaf (shape, dtype). Groups
    may only stack layers whose arrays are congruent — e.g. two ELL
    layers with different realized K_max get distinct signatures."""
    aux = (pl.variant, pl.m_pat, pl.d_in, pl.d_out, pl.rank)
    leaves = tuple((None if a is None else (a.shape, str(a.dtype)))
                   for a in (pl.sparse_vals, pl.sparse_idx, pl.b_packed,
                             pl.u, pl.v))
    return aux + leaves


def _describe(pl) -> str:
    if isinstance(pl, ExpertPackedStack):
        parts = [f"{_describe(jax.tree.map(lambda a: a[0], g))} x{len(m)}"
                 for g, m in zip(pl.groups, pl.members)]
        if pl.dense_members:
            parts.append(f"dense x{len(pl.dense_members)}")
        return "experts[" + " | ".join(parts) + "]"
    d = pl.variant
    if pl.m_pat:
        d += f"({pl.sparse_vals.shape[-1]}:{pl.m_pat})"
    elif pl.variant.endswith("-ell"):
        d += f"(kmax={pl.sparse_vals.shape[-1]})"
    if pl.rank:
        d += f" r{pl.rank}"
    return d


def _leaf_signature(leaf) -> Tuple:
    """Layer-stacking key for any per-layer packed leaf."""
    if isinstance(leaf, ExpertPackedStack):
        return (("experts", leaf.members, leaf.dense_members,
                 leaf.n_experts)
                + tuple(_pack_signature(g) for g in leaf.groups)
                + ((None if leaf.dense is None
                    else (leaf.dense.shape, str(leaf.dense.dtype))),))
    return _pack_signature(leaf)


# How many quantization buckets the per-expert realized ELL K_max is
# split into: within a bucket experts pad to the bucket's realized max,
# so a few hot experts don't inflate every expert's pad width, while
# the number of grouped-kernel launches stays bounded.
EXPERT_KMAX_BUCKETS = 4


def pack_expert_stack(old: Array,
                      e_decs: Tuple[SLaBDecomposition, ...],
                      pattern: Optional[str],
                      dtype=jnp.float32,
                      n_buckets: int = EXPERT_KMAX_BUCKETS
                      ) -> ExpertPackedStack:
    """Pack one layer's 3-D MoE leaf from its per-expert decompositions.

    ``old`` is the model-orientation ``(E, D_in, D_out)`` expert leaf
    (kept for unservable experts' dense slices); ``e_decs`` the
    per-expert paper-orientation decs the pipeline produced. All
    experts classify from ONE fused device sync (per-expert realized
    row-nnz K_max + total nnz); ELL experts then bucket by quantized
    K_max — bucket width ``ceil(global_max / n_buckets)`` — and every
    bucket pads to its own realized max. Experts sharing a full packed
    signature stack into one grouped-kernel launch."""
    n_exp = len(e_decs)
    itemsize = jnp.dtype(dtype).itemsize
    # experts with no sparse plane at all (w_s=None decs) can't join the
    # fused nnz sync — they classify straight to the dense remainder
    servable = [e for e, d in enumerate(e_decs)
                if d.w_s is not None and d.w_s.ndim == 2]
    kmaxes = [1] * n_exp
    variants: List[Optional[str]] = [None] * n_exp
    if servable:
        ws = jnp.stack([e_decs[e].w_s for e in servable])
        row_nnz, tot_nnz = jax.device_get(
            (jnp.max(jnp.sum(ws != 0, axis=-1), axis=-1),
             jnp.sum(ws != 0, axis=(1, 2))))
        for i, e in enumerate(servable):
            kmaxes[e] = max(1, int(row_nnz[i]))
            variants[e] = variant_of(e_decs[e], pattern, itemsize,
                                     k_max=kmaxes[e],
                                     has_s=bool(tot_nnz[i]))
    q = max(1, -(-max(kmaxes) // n_buckets))
    pads: Dict[int, int] = {}
    for e, var in enumerate(variants):
        if var is not None and var.endswith("-ell"):
            b = (kmaxes[e] - 1) // q
            pads[b] = max(pads.get(b, 0), kmaxes[e])
    by_sig: Dict[Tuple, List[Tuple[int, PackedLinear]]] = {}
    dense_members: List[int] = []
    for e, (dec, var) in enumerate(zip(e_decs, variants)):
        if var is None:
            dense_members.append(e)
            continue
        nnz = (pads[(kmaxes[e] - 1) // q] if var.endswith("-ell")
               else kmaxes[e])
        pl = pack_linear(dec, pattern, dtype, variant=var, ell_nnz=nnz)
        by_sig.setdefault(_pack_signature(pl), []).append((e, pl))
    groups: List[PackedLinear] = []
    members: List[Tuple[int, ...]] = []
    for key in sorted(by_sig, key=str):
        es = by_sig[key]
        groups.append(_stack_group([pl for (_, pl) in es]))
        members.append(tuple(e for (e, _) in es))
    dense = (jnp.stack([old[e] for e in dense_members])
             if dense_members else None)
    return ExpertPackedStack(tuple(groups), dense, tuple(members),
                             tuple(dense_members), n_exp)


def _model_segments(layers_tree, n_layers: int,
                    paths: List[str]) -> Tuple[Segment, ...]:
    """The contiguous scan segments of a packed layers tree plus, per
    segment, the (path, variant descriptor) signature serve.py prints."""
    from repro.core.pipeline import _get
    segs = []
    for lo, hi in segment_runs(layers_tree, n_layers):
        sig = []
        for p in paths:
            leaf = _get(layers_tree, p)
            if isinstance(leaf, PackedStack):
                gi = leaf.owner_group(lo)
                desc = ("dense" if gi < 0
                        else _describe(jax.tree.map(lambda a: a[0],
                                                    leaf.groups[gi])))
            else:
                desc = _describe(jax.tree.map(lambda a: a[0], leaf))
            sig.append((p, desc))
        segs.append(Segment(lo, hi, tuple(sig)))
    return tuple(segs)


def pack_plan_decs(params: dict,
                   decs: Dict[Tuple[int, str], SLaBDecomposition],
                   n_layers: int, plan,
                   dtype=jnp.float32,
                   variants: Optional[Dict[Tuple[int, str], str]] = None,
                   planner=None
                   ) -> Tuple[dict, PackReport]:
    """Pack EVERY servable decomposition of a (possibly mixed-method)
    plan — mixed variants, mixed N:M patterns, mixed ranks, and partial
    layer coverage per path all pack:

      * layers of one path with the same packed signature (variant aux
        + array shapes) stack into one scan-sliceable group;
      * a path whose single group covers all layers stays a plain
        stacked PackedLinear (one-scan fast path);
      * anything else becomes a PackedStack of signature groups plus
        the dense remainder, and the model scans the maximal contiguous
        same-signature layer runs (``segment_runs``).

    Patterns come from each dec's own resolved plan rule (per (layer,
    path) — not layer 0's), so paths whose early layers are skipped or
    use different rules pack fine. ``variants`` optionally supplies the
    per-(layer, path) classification the pipeline already computed
    (``CompressStats.variant``; "" = unservable) so the per-linear
    ``variant_of`` device sync isn't paid twice.

    ``planner`` (a ``runtime.sharding.Planner``) makes packing mesh-
    aware: each packed leaf is placed with the NamedShardings of its
    per-variant axes tree (``packed_axes``) the moment it is built —
    leaves are *born sharded* instead of replicated then resharded —
    and the per-segment slice cache is warmed after placement, so the
    pre-sliced scan inputs carry the shards too.

    3-D MoE leaves arrive as TUPLES of per-expert decs (the pipeline's
    expert branch) and pack into per-layer ``ExpertPackedStack``s
    (K_max-bucketed grouped-kernel launches); hybrid shared-block decs
    arrive under ``shared.*`` names (keyed at the firing layer) and
    pack once into ``params["shared_attn"]``. Still-dense bytes —
    unservable decs, plan-uncovered layers of packed paths, and
    unservable experts — aggregate under the ``"dense-fallback"``
    pseudo-variant so the bytes summary reflects true model bytes for
    partially packed models. Returns (params, PackReport); a warning is
    emitted for any packed variant whose measured bytes exceed its
    dense footprint."""
    from repro.core.pipeline import _get, _set

    pack_itemsize = jnp.dtype(dtype).itemsize
    by_path: Dict[str, Dict[Tuple,
                            List[Tuple[int, PackedLinear]]]] = {}
    expert_by_path: Dict[str, Dict[int, ExpertPackedStack]] = {}
    shared_pls: List[Tuple[int, str, PackedLinear]] = []
    fallback: List[Tuple[int, str]] = []
    n_packed = 0
    by_variant: Dict[str, int] = {}
    bytes_by_variant: Dict[str, List[float]] = {}

    def _agg(var: str, packed_b: float, dense_b: float, n: int = 1):
        a = bytes_by_variant.setdefault(var, [0.0, 0.0, 0])
        a[0] += packed_b
        a[1] += dense_b
        a[2] += n

    for (l, name) in sorted(decs, key=lambda k: (k[1], k[0])):
        dec = decs[(l, name)]
        r = plan.resolve(l, name)
        pattern = r.scfg.pattern if r is not None else None
        # a plain tuple of per-expert decs marks a 3-D MoE leaf
        # (SLaBDecomposition itself is a NamedTuple — exact type check)
        if type(dec) is tuple:
            old = _get(params["layers"], name)
            if old is None:
                fallback.append((l, name))
                continue
            expert_by_path.setdefault(name, {})[l] = \
                pack_expert_stack(old[l], dec, pattern, dtype)
            continue
        # the row-nnz device sync is LAZY: a pipeline-supplied dense-kind
        # variant at matching dtypes pays zero extra syncs, and an
        # ELL-routed linear pays exactly one (shared by the dtype
        # revalidation and ell_pack's pad width)
        k_max = None
        if variants is not None and (l, name) in variants:
            var = variants[(l, name)] or None
            if (var is not None and var.endswith(("-ell", "-dense"))
                    and dec.w_s.dtype.itemsize != pack_itemsize):
                # the pipeline classified at the dec's own dtype; the
                # ELL-vs-dense bytes race depends on the PACK dtype
                k_max = ell_row_nnz_max(dec.w_s)
                base = var.rsplit("-", 1)[0]
                var = (f"{base}-"
                       f"{_unstructured_kind(dec.w_s, pack_itemsize, k_max)}")
        else:
            var = variant_of(dec, pattern, itemsize=pack_itemsize)
        if var is None:
            fallback.append((l, name))
            continue
        if var.endswith("-ell") and k_max is None:
            k_max = ell_row_nnz_max(dec.w_s)
        pl = pack_linear(dec, pattern, dtype, variant=var,
                         ell_nnz=k_max if var.endswith("-ell") else None)
        if name.startswith("shared."):
            shared_pls.append((l, name, pl))
            continue
        by_path.setdefault(name, {}).setdefault(
            _pack_signature(pl), []).append((l, pl))

    out = jax.tree.map(lambda a: a, params)     # shallow copy
    packed_paths: List[str] = []
    for name, groups in sorted(by_path.items()):
        old = _get(out["layers"], name)
        if old is None:
            fallback.extend((l, name) for vs in groups.values()
                            for (l, _) in vs)
            continue
        per_dense = old.nbytes / old.shape[0]
        stacked_groups: List[PackedLinear] = []
        members: List[Tuple[int, ...]] = []
        for key in sorted(groups, key=str):
            layers = groups[key]
            var = layers[0][1].variant
            stacked_groups.append(_stack_group([pl for (_, pl) in layers]))
            members.append(tuple(l for (l, _) in layers))
            by_variant[var] = by_variant.get(var, 0) + len(layers)
            n_packed += len(layers)
            for (_, pl) in layers:
                _agg(var, sum(a.nbytes for a in jax.tree.leaves(pl)),
                     per_dense)
        covered = {l for mem in members for l in mem}
        missing = tuple(l for l in range(n_layers) if l not in covered)
        if not missing and len(stacked_groups) == 1:
            leaf = stacked_groups[0]            # one-scan fast path
        else:
            dense = (jnp.stack([old[l] for l in missing])
                     if missing else None)
            leaf = PackedStack(tuple(stacked_groups), dense,
                               tuple(members), missing, n_layers)
            if missing:
                _agg("dense-fallback", per_dense * len(missing),
                     per_dense * len(missing), len(missing))
        if planner is not None:
            # pack AFTER placement: the leaf materializes with its
            # per-variant NamedShardings rather than being replicated
            # first and resharded by the first constrained step
            leaf = jax.device_put(
                leaf, planner.tree_shardings(packed_axes(leaf), leaf))
        _set(out["layers"], name, leaf)
        packed_paths.append(name)

    # ---- expert-axis (3-D MoE) paths ----
    for name, per_layer in sorted(expert_by_path.items()):
        old = _get(out["layers"], name)
        per_dense_e = old.nbytes / (old.shape[0] * old.shape[1])
        by_sig: Dict[Tuple, List[Tuple[int, ExpertPackedStack]]] = {}
        for l, eps in sorted(per_layer.items()):
            for grp, mem in zip(eps.groups, eps.members):
                var = grp.variant
                by_variant[var] = by_variant.get(var, 0) + len(mem)
                n_packed += len(mem)
                _agg(var, sum(a.nbytes for a in jax.tree.leaves(grp)),
                     per_dense_e * len(mem), len(mem))
            for e in eps.dense_members:
                fallback.append((l, f"{name}[expert {e}]"))
                _agg("dense-fallback", per_dense_e, per_dense_e)
            by_sig.setdefault(_leaf_signature(eps), []).append((l, eps))
        stacked_groups = []
        members = []
        for key in sorted(by_sig, key=str):
            ls = by_sig[key]
            stacked_groups.append(_stack_group([e for (_, e) in ls]))
            members.append(tuple(l for (l, _) in ls))
        covered = {l for mem in members for l in mem}
        missing = tuple(l for l in range(n_layers) if l not in covered)
        if not missing and len(stacked_groups) == 1:
            leaf = stacked_groups[0]            # one-scan fast path
        else:
            dense = (jnp.stack([old[l] for l in missing])
                     if missing else None)
            leaf = PackedStack(tuple(stacked_groups), dense,
                               tuple(members), missing, n_layers)
            if missing:
                n_e = old.shape[1]
                _agg("dense-fallback", per_dense_e * n_e * len(missing),
                     per_dense_e * n_e * len(missing), n_e * len(missing))
        if planner is not None:
            leaf = jax.device_put(
                leaf, planner.tree_shardings(packed_axes(leaf), leaf))
        _set(out["layers"], name, leaf)
        packed_paths.append(name)

    # ---- hybrid shared-block paths (packed once, outside the stack) ----
    for l, name, pl in sorted(shared_pls, key=lambda t: t[1]):
        sub = name.split(".", 1)[1]
        old = _get(out.get("shared_attn", {}), sub)
        if old is None:
            fallback.append((l, name))
            continue
        if planner is not None:
            pl = jax.device_put(
                pl, planner.tree_shardings(packed_axes(pl), pl))
        _set(out["shared_attn"], sub, pl)
        by_variant[pl.variant] = by_variant.get(pl.variant, 0) + 1
        n_packed += 1
        _agg(pl.variant, sum(a.nbytes for a in jax.tree.leaves(pl)),
             float(old.nbytes))
        packed_paths.append(name)

    # unservable decs stayed dense: their bytes count toward the model too
    for (l, fname) in fallback:
        base = fname.split("[", 1)[0]
        if base.startswith("shared."):
            w = _get(out.get("shared_attn", {}), base.split(".", 1)[1])
            if w is not None and not isinstance(w, PackedLinear):
                _agg("dense-fallback", float(w.nbytes), float(w.nbytes))
        elif "[expert " not in fname:           # expert slices counted above
            wp = _get(params["layers"], base)
            if wp is not None:
                _agg("dense-fallback", wp.nbytes / wp.shape[0],
                     wp.nbytes / wp.shape[0])

    per_linear = {var: (p / n, d / n)
                  for var, (p, d, n) in bytes_by_variant.items()}
    for var, (p, d) in sorted(per_linear.items()):
        if p > d:
            warnings.warn(
                f"packed variant {var!r} stores {p / d:.2f}x its dense "
                f"bytes ({p / 1e3:.1f} kB vs {d / 1e3:.1f} kB per linear)"
                " — this format loses on the serving roofline",
                stacklevel=2)
    layer_paths = [p for p in packed_paths if not p.startswith("shared.")]
    segments = _model_segments(out["layers"], n_layers, layer_paths)
    # pre-slice every (stack, run) pair once, at pack time: decode-step
    # traces then reuse the memoized (and, under a planner, sharded)
    # segment leaves instead of re-slicing the layer axis per trace
    stacks = [l for l in jax.tree.leaves(out["layers"],
                                         is_leaf=_is_packed_leaf)
              if isinstance(l, PackedStack)]
    for seg in segments:
        for s in stacks:
            s.segment(seg.lo, seg.hi)
    return out, PackReport(n_packed, by_variant, packed_paths,
                           sorted(fallback, key=lambda k: (k[1], k[0])),
                           segments, per_linear)


def pack_model(params: dict,
               decs: Dict[Tuple[int, str], SLaBDecomposition],
               n_layers: int,
               pattern: Optional[str] = None,
               dtype=jnp.float32) -> dict:
    """Single-pattern convenience packer: replace each fully-covered
    decomposed path in the stacked-params tree with a stacked
    PackedLinear (partial-coverage paths are skipped — use
    ``pack_plan_decs`` for the general mixed/partial case). ``decs``
    comes from core.pipeline.compress_model (keep_decompositions=True)."""
    from repro.core.pipeline import _get, _set
    out = jax.tree.map(lambda a: a, params)     # shallow copy
    paths = sorted({p for (_, p) in decs})
    itemsize = jnp.dtype(dtype).itemsize
    for path in paths:
        if any((l, path) not in decs for l in range(n_layers)):
            continue                             # partial coverage: skip
        if any(type(decs[(l, path)]) is tuple for l in range(n_layers)):
            continue         # 3-D expert tuples need pack_plan_decs
        variants = [variant_of(decs[(l, path)], pattern, itemsize)
                    for l in range(n_layers)]
        if len(set(variants)) != 1 or variants[0] is None:
            continue                             # mixed variants: skip
        # ELL layers of one path pack at the shared per-path K_max so
        # ragged realized widths still stack (a few pad columns beat
        # silently losing the whole path to dense)
        ell_nnz = None
        if variants[0].endswith("-ell"):
            ell_nnz = max(ell_row_nnz_max(decs[(l, path)].w_s)
                          for l in range(n_layers))
        per_layer = [pack_linear(decs[(l, path)], pattern, dtype,
                                 variant=variants[l], ell_nnz=ell_nnz)
                     for l in range(n_layers)]
        if len({_pack_signature(pl) for pl in per_layer}) != 1:
            continue                             # incongruent terms: skip
        _set(out["layers"], path, _stack_group(per_layer))
    return out
