"""Packed-weight model serving: every compressed linear lives in an
on-HBM packed format (N:M values+indices or dense-masked W_S, bit-packed
W_B, rank-r u/v factors) and forwards through the fused Pallas kernels.

``PackedLinear`` is a **variant-tagged** registered pytree: the arrays
that exist depend on which decomposition terms the compressor produced,
and a static ``variant`` tag picks the kernel at dispatch time:

  variant          terms                       kernel
  ---------------  --------------------------  ---------------------------
  slab-nm          N:M W_S + W_B + rank-r UV   ops.slab_nm_matmul
  slab-dense       dense W_S + W_B + rank-r    ops.slab_matmul
  binlr            W_B + rank-r UV (no W_S)    ops.binlr
  lowrank-nm       N:M W_S + rank-r UV         ops.slab_nm_lr_matmul
  lowrank-dense    dense W_S + rank-r UV       ops.slab_lr_matmul
  lowrank          rank-r UV only              (x @ V) @ Uᵀ (XLA; already
                                               minimal bytes)
  sparse-nm        N:M W_S only                ops.nm_matmul
  sparse-dense     dense-masked W_S only       x @ W_Sᵀ (XLA; dense-masked
                                               bytes equal dense — the
                                               format tag still marks the
                                               linear as served-in-format)

Static metadata (variant, m_pat, d_in, d_out, rank) rides in the pytree
aux data, so stacks of packed layers slice cleanly through ``lax.scan``
and ``jax.tree.map`` like any other parameter — and tree operations
refuse to mix variants (aux mismatch), which is exactly the stacking
invariant the packer enforces.

Heterogeneous paths — different variants/patterns/ranks across layers of
one path, or partial layer coverage — pack into a ``PackedStack``:
segmented per-variant stacks keyed by (variant, pattern, rank) plus an
optional stacked dense remainder. A PackedStack cannot slice through one
``lax.scan`` (leaf shapes differ per layer), so ``models.lm`` unrolls
the layer loop when one is present; fully-covered single-variant paths
keep the scanned fast path.

CPU note: Mosaic only compiles on TPU; on CPU the kernels run in
interpret mode (numerics-exact, slow) — the packed path is exercised by
tests/examples at smoke scale and is the TPU serving configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import pack_nm, pack_sign_bits
from repro.core.slab import SLaBDecomposition
from repro.models.common import tap_record

Array = jax.Array

PACKED_VARIANTS = ("slab-nm", "slab-dense", "binlr", "lowrank-nm",
                   "lowrank-dense", "lowrank", "sparse-nm", "sparse-dense")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedLinear:
    """One compressed linear, model-orientation (computes x @ Wᵀ for the
    paper's (D_out, D_in) W — i.e. a drop-in for x @ w, w (D_in, D_out)).

    Array fields are pytree children (absent terms are None); the
    variant tag and shape metadata are static aux data, preserved by
    stacking/slicing and checked for equality by tree operations.

    sparse_vals : (D_out, D_in) dense-masked W_S, or (D_out, D_in/m, n)
                  N:M values, or None.
    sparse_idx  : (D_out, D_in/m, n) int8 N:M positions, or None.
    b_packed    : (D_out, D_in/32) uint32 sign bits, or None.
    u, v        : (D_out, r) / (D_in, r) low-rank factors, or None.
    """

    sparse_vals: Optional[Array]
    sparse_idx: Optional[Array]
    b_packed: Optional[Array]
    u: Optional[Array]
    v: Optional[Array]
    variant: str = "slab-dense"
    m_pat: int = 0            # N:M group size m (0 = not N:M)
    d_in: int = 0
    d_out: int = 0
    rank: int = 0

    def tree_flatten(self):
        return ((self.sparse_vals, self.sparse_idx, self.b_packed,
                 self.u, self.v),
                (self.variant, self.m_pat, self.d_in, self.d_out,
                 self.rank))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedStack:
    """Segmented packed stacks for one linear path across the layer dim.

    ``groups[g]`` is a PackedLinear stacked over ``members[g]`` (layer
    ids, ascending); ``dense`` is the original stacked weight restricted
    to ``dense_members`` — layers the plan left dense (partial
    coverage). Membership is static aux data so ``at_layer`` resolves at
    trace time; the model unrolls its layer loop over one of these.
    """

    groups: Tuple[PackedLinear, ...]
    dense: Optional[Array]
    members: Tuple[Tuple[int, ...], ...]
    dense_members: Tuple[int, ...]
    n_layers: int

    def tree_flatten(self):
        return ((self.groups, self.dense),
                (self.members, self.dense_members, self.n_layers))

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], *aux)

    def at_layer(self, l: int):
        """The layer-``l`` leaf: a sliced PackedLinear or a dense 2-D
        weight (in model (D_in, D_out) orientation)."""
        for grp, mem in zip(self.groups, self.members):
            if l in mem:
                i = mem.index(l)
                return jax.tree.map(lambda a: a[i], grp)
        if l in self.dense_members:
            return self.dense[self.dense_members.index(l)]
        raise KeyError(f"layer {l} not held by this PackedStack")

    def variant_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for grp, mem in zip(self.groups, self.members):
            out[grp.variant] = out.get(grp.variant, 0) + len(mem)
        return out


def _is_packed_leaf(x) -> bool:
    return isinstance(x, (PackedLinear, PackedStack))


def has_hetero(tree) -> bool:
    """True if any leaf is a PackedStack (forces the unrolled layer
    loop; homogeneous stacked PackedLinears scan fine)."""
    return any(isinstance(l, PackedStack)
               for l in jax.tree.leaves(tree, is_leaf=_is_packed_leaf))


def layer_slice(tree, l: int):
    """Slice a stacked layers tree at layer ``l``, resolving PackedStack
    leaves to their layer-``l`` representation."""
    def f(x):
        if isinstance(x, PackedStack):
            return x.at_layer(l)
        if isinstance(x, PackedLinear):
            return jax.tree.map(lambda a: a[l], x)
        return x[l]
    return jax.tree.map(f, tree, is_leaf=_is_packed_leaf)


# ------------------------------------------------------------------
# Variant classification + per-linear packing
# ------------------------------------------------------------------

def _dec_rank(dec: SLaBDecomposition) -> int:
    if dec.u is None or not dec.u.size:
        return 0
    return dec.u.shape[1] if dec.u.ndim == 2 else 1


def variant_of(dec: SLaBDecomposition,
               pattern: Optional[str]) -> Optional[str]:
    """Classify one decomposition into its packed-serving variant (None
    = not representable; stays dense). The binary term only counts when
    a low-rank factor exists — W_L ⊙ W_B with empty W_L is identically
    zero (see core.slab.low_rank_times_binary), so a lone W_B carries no
    signal and the sparse part serves alone."""
    if dec.w_s is None or dec.w_s.ndim != 2:
        return None
    rank = _dec_rank(dec)
    has_b = (dec.w_b is not None and dec.w_b.size > 0 and rank > 0)
    if not has_b and rank == 0:
        # pruning-only dec: the sparse part is the only term, so no
        # device sync is needed to disambiguate (an all-zero W_S would
        # just serve zeros — same as its dense equivalent)
        return f"sparse-{'nm' if pattern else 'dense'}"
    has_s = bool(dec.w_s.size) and bool(jnp.any(dec.w_s != 0))
    kind = ("nm" if pattern else "dense") if has_s else None
    if has_b:
        return f"slab-{kind}" if kind else "binlr"
    if rank > 0:
        return f"lowrank-{kind}" if kind else "lowrank"
    return f"sparse-{kind}" if kind else None


def pack_linear(dec: SLaBDecomposition, pattern: Optional[str],
                dtype=jnp.float32,
                variant: Optional[str] = None) -> PackedLinear:
    """Pack one decomposition into its variant's storage format."""
    d_out, d_in = dec.w_s.shape
    variant = variant_of(dec, pattern) if variant is None else variant
    if variant is None:
        raise ValueError("decomposition has no packable terms")
    rank = _dec_rank(dec)
    u = v = bp = vals = idx = None
    m_pat = 0
    if rank:
        u = (dec.u if dec.u.ndim == 2 else dec.u[:, None]).astype(dtype)
        v = (dec.v if dec.v.ndim == 2 else dec.v[:, None]).astype(dtype)
    if variant.startswith("slab-") or variant == "binlr":
        bp = pack_sign_bits(dec.w_b)
    if variant.endswith("-nm"):
        n, m_pat = map(int, pattern.split(":"))
        # strict: a rule pattern that disagrees with the compressor's
        # actual output must fail loudly, not drop values
        nm = pack_nm(dec.w_s.astype(dtype), n, m_pat, strict=True)
        vals, idx = nm.values, nm.indices
    elif variant.endswith("-dense") or variant.startswith("sparse"):
        vals = dec.w_s.astype(dtype)
    return PackedLinear(vals, idx, bp, u, v, variant=variant, m_pat=m_pat,
                        d_in=d_in, d_out=d_out, rank=rank)


def packed_matmul(x: Array, w: PackedLinear,
                  interpret: Optional[bool] = None) -> Array:
    """x (..., D_in) @ Wᵀ through the variant's fused kernel."""
    from repro.kernels import ops
    bk = min(512, w.d_in)
    kw = dict(bm=128, bn=128, bk=bk, interpret=interpret)
    var = w.variant
    if var == "slab-nm":
        y = ops.slab_nm_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat,
                               w.b_packed, w.u, w.v, **kw)
    elif var == "slab-dense":
        y = ops.slab_matmul(x, w.sparse_vals.astype(x.dtype), w.b_packed,
                            w.u, w.v, **kw)
    elif var == "binlr":
        y = ops.binlr(x, w.b_packed, w.u, w.v, **kw)
    elif var == "lowrank-nm":
        y = ops.slab_nm_lr_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat,
                                  w.u, w.v, **kw)
    elif var == "lowrank-dense":
        y = ops.slab_lr_matmul(x, w.sparse_vals.astype(x.dtype),
                               w.u, w.v, **kw)
    elif var == "lowrank":
        # two skinny XLA matmuls: r(D_in + D_out) weights per token —
        # already the minimal-byte form, nothing left to fuse
        y = (x.astype(jnp.float32) @ w.v.astype(jnp.float32)) \
            @ w.u.astype(jnp.float32).T
    elif var == "sparse-nm":
        y = ops.nm_matmul(x, w.sparse_vals, w.sparse_idx, w.m_pat, **kw)
    elif var == "sparse-dense":
        # dense-masked bytes equal dense bytes: a plain dot IS the
        # optimal serve; the tag records the linear as served-in-format
        y = x @ w.sparse_vals.astype(x.dtype).T
    else:
        raise ValueError(f"unknown packed variant {var!r}")
    return y.astype(x.dtype)


def linear(x: Array, w, tap: Optional[str] = None) -> Array:
    """Dispatch point used by the model layers: dense `x @ w` or the
    packed fused kernel. ``tap`` names this linear for activation
    capture (models.common.tap_capture): when a capture is active the
    exact input ``x`` is reported under the current tap scope before
    the matmul runs; otherwise it's a no-op."""
    if tap is not None:
        tap_record(tap, x)
    if isinstance(w, PackedLinear):
        return packed_matmul(x, w)
    return x @ w


# ------------------------------------------------------------------
# Whole-model packing
# ------------------------------------------------------------------

class PackReport(NamedTuple):
    """What pack_plan_decs did: per-variant packed-linear counts, the
    packed paths, and the (layer, path) decs left on the dense path."""
    n_packed: int
    by_variant: Dict[str, int]
    paths: List[str]
    fallback: List[Tuple[int, str]]


def _stack_group(pls: List[PackedLinear]) -> PackedLinear:
    if len(pls) == 1:
        return jax.tree.map(lambda a: a[None], pls[0])
    return jax.tree.map(lambda *xs: jnp.stack(xs), *pls)


def pack_plan_decs(params: dict,
                   decs: Dict[Tuple[int, str], SLaBDecomposition],
                   n_layers: int, plan,
                   dtype=jnp.float32,
                   variants: Optional[Dict[Tuple[int, str], str]] = None
                   ) -> Tuple[dict, PackReport]:
    """Pack EVERY servable decomposition of a (possibly mixed-method)
    plan — mixed variants, mixed N:M patterns, mixed ranks, and partial
    layer coverage per path all pack:

      * layers of one path with the same (variant, pattern, rank) stack
        into one scan-sliceable group;
      * a path whose single group covers all layers stays a plain
        stacked PackedLinear (the lax.scan fast path);
      * anything else becomes a PackedStack of segmented groups plus
        the dense remainder, and the model unrolls its layer loop.

    Patterns come from each dec's own resolved plan rule (per (layer,
    path) — not layer 0's), so paths whose early layers are skipped or
    use different rules pack fine. ``variants`` optionally supplies the
    per-(layer, path) classification the pipeline already computed
    (``CompressStats.variant``; "" = unservable) so the per-linear
    ``variant_of`` device sync isn't paid twice. Returns
    (params, PackReport)."""
    from repro.core.pipeline import _get, _set

    by_path: Dict[str, Dict[Tuple[str, Optional[str], int],
                            List[Tuple[int, SLaBDecomposition,
                                       Optional[str]]]]] = {}
    fallback: List[Tuple[int, str]] = []
    for (l, name) in sorted(decs, key=lambda k: (k[1], k[0])):
        dec = decs[(l, name)]
        r = plan.resolve(l, name)
        pattern = r.scfg.pattern if r is not None else None
        if variants is not None and (l, name) in variants:
            var = variants[(l, name)] or None
        else:
            var = variant_of(dec, pattern)
        if var is None:
            fallback.append((l, name))
            continue
        key = (var, pattern if var.endswith("-nm") else None,
               _dec_rank(dec))
        by_path.setdefault(name, {}).setdefault(key, []).append(
            (l, dec, pattern))

    out = jax.tree.map(lambda a: a, params)     # shallow copy
    n_packed = 0
    by_variant: Dict[str, int] = {}
    packed_paths: List[str] = []
    for name, groups in sorted(by_path.items()):
        old = _get(out["layers"], name)
        if old is None:
            fallback.extend((l, name) for vs in groups.values()
                            for (l, _, _) in vs)
            continue
        stacked_groups: List[PackedLinear] = []
        members: List[Tuple[int, ...]] = []
        for key in sorted(groups, key=str):
            var = key[0]
            layers = groups[key]
            pls = [pack_linear(dec, pat, dtype, variant=var)
                   for (_, dec, pat) in layers]
            stacked_groups.append(_stack_group(pls))
            members.append(tuple(l for (l, _, _) in layers))
            by_variant[var] = by_variant.get(var, 0) + len(layers)
            n_packed += len(layers)
        covered = {l for mem in members for l in mem}
        missing = tuple(l for l in range(n_layers) if l not in covered)
        if not missing and len(stacked_groups) == 1:
            leaf = stacked_groups[0]            # lax.scan fast path
        else:
            dense = (jnp.stack([old[l] for l in missing])
                     if missing else None)
            leaf = PackedStack(tuple(stacked_groups), dense,
                               tuple(members), missing, n_layers)
        _set(out["layers"], name, leaf)
        packed_paths.append(name)
    return out, PackReport(n_packed, by_variant, packed_paths,
                           sorted(fallback, key=lambda k: (k[1], k[0])))


def pack_model(params: dict,
               decs: Dict[Tuple[int, str], SLaBDecomposition],
               n_layers: int,
               pattern: Optional[str] = None,
               dtype=jnp.float32) -> dict:
    """Single-pattern convenience packer: replace each fully-covered
    decomposed path in the stacked-params tree with a stacked
    PackedLinear (partial-coverage paths are skipped — use
    ``pack_plan_decs`` for the general mixed/partial case). ``decs``
    comes from core.pipeline.compress_model (keep_decompositions=True)."""
    from repro.core.pipeline import _get, _set
    out = jax.tree.map(lambda a: a, params)     # shallow copy
    paths = sorted({p for (_, p) in decs})
    for path in paths:
        if any((l, path) not in decs for l in range(n_layers)):
            continue                             # partial coverage: skip
        variants = [variant_of(decs[(l, path)], pattern)
                    for l in range(n_layers)]
        if len(set(variants)) != 1 or variants[0] is None:
            continue                             # mixed variants: skip
        per_layer = [pack_linear(decs[(l, path)], pattern, dtype,
                                 variant=variants[l])
                     for l in range(n_layers)]
        _set(out["layers"], path, _stack_group(per_layer))
    return out
