"""Packed-weight model serving: every compressed linear lives in the
SLaB on-HBM format (N:M values+indices or dense-masked W_S, bit-packed
W_B, rank-1 u/v) and forwards through the fused Pallas kernels.

`PackedLinear` is a pure-array NamedTuple (all static metadata — the
N:M pattern, D_in — is derivable from leaf shapes), so stacks of packed
layers slice cleanly through `lax.scan` like any other parameter.

CPU note: Mosaic only compiles on TPU; on CPU the kernels run in
interpret mode (numerics-exact, slow) — the packed path is exercised by
tests/examples at smoke scale and is the TPU serving configuration.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import pack_nm, pack_sign_bits
from repro.core.slab import SLaBDecomposition
from repro.models.common import tap_record

Array = jax.Array


class PackedLinear(NamedTuple):
    """One compressed linear, model-orientation (computes x @ Wᵀ for the
    paper's (D_out, D_in) W — i.e. a drop-in for x @ w, w (D_in, D_out)).

    N:M mode: sparse_vals/idx (D_out, D_in/m, n); unstructured mode:
    sparse_vals is the dense-masked W_S (D_out, D_in) and sparse_idx is
    None (the documented TPU fallback — lane gathers are VPU-hostile).
    """
    sparse_vals: Array
    sparse_idx: Optional[Array]
    b_packed: Array          # (D_out, D_in/32) uint32
    u: Array                 # (D_out,)
    v: Array                 # (D_in,)


def pack_linear(dec: SLaBDecomposition, pattern: Optional[str],
                dtype=jnp.float32) -> PackedLinear:
    d_out, d_in = dec.w_s.shape
    u = (dec.u[:, 0] if dec.u.ndim == 2 else dec.u).astype(dtype)
    v = (dec.v[:, 0] if dec.v.ndim == 2 else dec.v).astype(dtype)
    bp = pack_sign_bits(dec.w_b)
    if pattern is not None:
        n, m = map(int, pattern.split(":"))
        nm = pack_nm(dec.w_s.astype(dtype), n, m)
        return PackedLinear(nm.values, nm.indices, bp, u, v)
    return PackedLinear(dec.w_s.astype(dtype), None, bp, u, v)


def packed_matmul(x: Array, w: PackedLinear,
                  interpret: Optional[bool] = None) -> Array:
    """x (..., D_in) @ Wᵀ through the fused kernel."""
    from repro.kernels import ops
    d_in = w.v.shape[-1]
    if w.sparse_idx is not None:
        m_pat = d_in // w.sparse_vals.shape[-2]
        return ops.slab_nm_matmul(
            x, w.sparse_vals, w.sparse_idx, m_pat, w.b_packed, w.u, w.v,
            bm=128, bn=128, bk=min(512, d_in), interpret=interpret
        ).astype(x.dtype)
    return ops.slab_matmul(
        x, w.sparse_vals.astype(x.dtype), w.b_packed, w.u, w.v,
        bm=128, bn=128, bk=min(512, d_in), interpret=interpret
    ).astype(x.dtype)


def linear(x: Array, w, tap: Optional[str] = None) -> Array:
    """Dispatch point used by the model layers: dense `x @ w` or the
    packed fused kernel. ``tap`` names this linear for activation
    capture (models.common.tap_capture): when a capture is active the
    exact input ``x`` is reported under the current tap scope before
    the matmul runs; otherwise it's a no-op."""
    if tap is not None:
        tap_record(tap, x)
    if isinstance(w, PackedLinear):
        return packed_matmul(x, w)
    return x @ w


def pack_plan_decs(params: dict,
                   decs: Dict[Tuple[int, str], SLaBDecomposition],
                   n_layers: int, plan) -> Tuple[dict, int, list]:
    """Pack the kernel-servable subset of a (possibly mixed-method)
    plan's decompositions: rank-1 decs with a binary term, full layer
    coverage per path, and one sparse format per path — the pattern
    each dec's resolved plan rule actually compressed with. Everything
    else stays on the dense XLA path. Returns
    (params, n_linears_packed, packed_paths)."""
    servable = {k: v for k, v in decs.items()
                if v.w_b is not None and v.w_b.size       # has W_B
                and v.u is not None and v.u.size          # has W_L
                and (v.u.ndim == 1 or v.u.shape[1] == 1)}  # rank 1
    pat_of = {}
    for (l, name) in servable:
        r = plan.resolve(l, name)
        pat_of[(l, name)] = r.scfg.pattern if r is not None else None
    coverage: Dict[str, int] = {}
    for (_, name) in servable:
        coverage[name] = coverage.get(name, 0) + 1
    paths = {name for name, n in coverage.items()
             if n == n_layers
             and len({pat_of[k] for k in servable if k[1] == name}) == 1}
    n_packed = 0
    for pat in {pat_of[(0, name)] for name in paths}:
        sub = {k: v for k, v in servable.items()
               if k[1] in paths and pat_of[k] == pat}
        params = pack_model(params, sub, n_layers, pattern=pat)
        n_packed += len(sub)
    return params, n_packed, sorted(paths)


def pack_model(params: dict,
               decs: Dict[Tuple[int, str], SLaBDecomposition],
               n_layers: int,
               pattern: Optional[str] = None) -> dict:
    """Replace each decomposed linear in the stacked-params tree with a
    stacked PackedLinear. ``decs`` comes from core.pipeline.compress_model
    (keep_decompositions=True)."""
    from repro.core.pipeline import _get, _set
    out = jax.tree.map(lambda a: a, params)     # shallow copy
    paths = sorted({p for (_, p) in decs})
    for path in paths:
        per_layer = [pack_linear(decs[(l, path)], pattern)
                     for l in range(n_layers)
                     if (l, path) in decs]
        if len(per_layer) != n_layers:
            continue                             # partial coverage: skip
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        _set(out["layers"], path, stacked)
    return out
