"""Sensitivity-driven per-layer compression-ratio allocation.

SLaB's headline quality comes from *per-layer decisions*: how much
budget each linear gets matters as much as how it is spent (the
ROADMAP's remaining gap; HASSLE-free and 1+1>2 report the same for
sparse+low-rank decompositions). This module closes that loop on the
statistics the activation taps already collect:

  1. **Probe** — from ONE streaming calibration pass
     (``core.pipeline.collect_model_stats``), sample each linear's
     CR→err_after frontier: at every candidate CR, the method's
     ``keep_fraction_for`` budget model picks the W_S keep fraction and
     the activation-weighted score mass that pruning at that budget
     discards predicts the error (exact for score-based pruners like
     ``wanda``/``magnitude``, a monotone proxy for ``slab``/``hassle``
     whose extra terms recover part of it). No forwards run per
     candidate — the frontier is pure per-matrix math on tapped norms.
  2. **Group** — tied weights share one CR: the hybrid ``shared.*``
     block (compressed once, fires at many layers) is a single group;
     ``granularity="layer"`` merges each layer's linears.
  3. **Solve** — discrete water-filling: start every group at its
     lowest admissible CR and repeatedly take the step with the least
     predicted-error increase per unit of size-weighted CR gained,
     until the global budget is met (floor/ceiling clamps respected).
     A uniform-at-budget allocation is evaluated as a fallback, so the
     result is never predicted-worse than the uniform plan.
  4. **Emit** — a concrete ``CompressionPlan``: one exact
     ``layer/path=method@cr=...`` rule per allocated linear, pinned
     (non-auto) template rules preserved behind them. The existing
     pipeline executes it with zero new execution paths; passing
     ``alloc.stats`` back to ``compress_model`` keeps the whole
     allocate+compress flow at exactly one calibration pass.

Reachable three ways: ``allocate_plan(...)`` here, an ``@auto`` plan
spec (``*=slab@auto; budget=0.5``) anywhere a plan is accepted, and
the ``--budget`` flag on ``launch/serve.py`` / ``benchmarks``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import plan as plan_lib
from repro.core import sparsity
from repro.core.pipeline import (ModelTapStats, _get, collect_model_stats,
                                 linear_paths, shared_linear_paths)
from repro.core.slab import SLaBConfig

# 0.05 .. 0.95 — dense enough that the budget is hit within ±2.5% per
# group, coarse enough that the probe stays a few masks per linear
DEFAULT_CANDIDATES = tuple(round(0.05 * i, 2) for i in range(1, 20))
DEFAULT_FLOOR = 0.05
DEFAULT_CEILING = 0.95


@dataclasses.dataclass
class Frontier:
    """Sampled CR → predicted-err_after curve for one allocation group.

    ``errs[i]`` predicts the summed ``CompressStats.err_after`` of the
    group's members at ``crs[i]`` (ascending, feasible candidates
    only); ``size`` is the member parameter count (the budget weight).
    """

    key: str
    size: int
    crs: np.ndarray
    errs: np.ndarray
    members: Tuple[Tuple[int, str], ...] = ()
    err_before: float = 0.0


@dataclasses.dataclass
class Allocation:
    """What ``allocate_plan`` decided (and the stats it probed from)."""

    plan: plan_lib.CompressionPlan       # concrete: per-rule cr pinned
    stats: ModelTapStats                 # pass to compress_model(stats=)
    crs: Dict[str, float]                # group key -> allocated CR
    rows: List[dict]                     # per (layer, path) report
    budget: float
    achieved: float                      # size-weighted requested CR
    predicted_err: float                 # summed predicted err_after

    def table(self) -> str:
        lines = [f"{'layer':>5}  {'path':<20} {'method':<10} "
                 f"{'cr':>6}  {'pred err_after':>14}"]
        for r in self.rows:
            lines.append(f"{r['layer']:>5}  {r['path']:<20} "
                         f"{r['method']:<10} {r['cr']:>6.3f}  "
                         f"{r['err_after']:>14.4g}")
        lines.append(f"budget={self.budget:.3f} -> achieved "
                     f"{self.achieved:.3f} (size-weighted), predicted "
                     f"err sum {self.predicted_err:.4g}")
        return "\n".join(lines)


def measured_global_cr(params: dict, rows) -> float:
    """Size-weighted measured CR over ``CompressStats`` rows — the
    quantity ``budget`` targets (parameter-count weights; hybrid
    ``shared.*`` rows weigh their ``shared_attn`` leaves)."""
    tot = wsum = 0.0
    for s in rows:
        if s.name.startswith("shared."):
            w = _get(params.get("shared_attn", {}), s.name.split(".", 1)[1])
            sz = 0.0 if w is None else float(np.asarray(w).size)
        else:
            leaf = _get(params["layers"], s.name)
            sz = 0.0 if leaf is None else float(leaf[s.layer].size)
        tot += sz
        wsum += sz * s.cr
    return wsum / max(tot, 1.0)


# ------------------------------------------------------------------
# Sensitivity probe
# ------------------------------------------------------------------

def _group_cum(s2: np.ndarray, group) -> Tuple[np.ndarray, int]:
    """Per-comparison-group ascending cumulative score mass: tiles
    exactly like ``sparsity.group_topk_mask`` (gcd fallback included);
    ``cum[:, p-1]`` is each group's p smallest squared scores summed —
    so the pruned mass of keeping top-k is ``cum[:, gsz-k-1]``. Exact
    for unstructured group top-k pruning (ties carry equal mass)."""
    d_out, d_in = s2.shape
    g_rows = group[0] or d_out
    g_cols = group[1] or d_in
    if d_out % g_rows or d_in % g_cols:
        g_rows = math.gcd(g_rows, d_out)
        g_cols = math.gcd(g_cols, d_in)
    gsz = g_rows * g_cols
    s = s2.reshape(d_out // g_rows, g_rows, d_in // g_cols, g_cols)
    s = s.transpose(0, 2, 1, 3).reshape(-1, gsz)
    return np.cumsum(np.sort(s, axis=1), axis=1), gsz


def _leaf_curve(w, norms, comp, candidates: Sequence[float]
                ) -> Tuple[Dict[float, float], float]:
    """(cr -> predicted err_after, err_before) for one parameter leaf in
    model orientation: (D_in, D_out) 2-D or (E, D_in, D_out) stacked
    experts. Infeasible candidates (keep fraction <= 0, or above an
    N:M pattern ceiling) are simply absent from the curve.

    Unstructured rules evaluate every candidate from ONE sort per
    matrix (the group-wise cumulative score mass); N:M rules fall back
    to the real ``prune_mask`` per candidate (the pre-mask interacts
    with the group top-k)."""
    arr = np.asarray(w, np.float32)
    if arr.ndim == 3:
        mats = [arr[e].T for e in range(arr.shape[0])]
        nrm = (None if norms is None else np.asarray(norms, np.float32))
        nrms = [None if nrm is None else (nrm[e] if nrm.ndim == 2 else nrm)
                for e in range(arr.shape[0])]
    else:
        mats = [arr.T]
        nrms = [None if norms is None else np.asarray(norms, np.float32)]
    d_out, d_in = mats[0].shape
    smats = [np.abs(m) * (n[None, :] if n is not None else 1.0)
             for m, n in zip(mats, nrms)]
    s2 = [(s.astype(np.float64)) ** 2 for s in smats]
    err_before = math.sqrt(sum(float(np.sum(x)) for x in s2))
    unstructured = comp.scfg.pattern is None
    if unstructured:
        cums = [_group_cum(x2, comp.scfg.group) for x2 in s2]

    curve: Dict[float, float] = {}
    for cr in candidates:
        frac = comp.keep_fraction_for(float(cr), d_out, d_in)
        if frac <= 0.0:
            continue
        err2 = 0.0
        ok = True
        if unstructured:
            for cum, gsz in cums:
                p = gsz - min(int(math.floor(frac * gsz)), gsz)
                if p > 0:
                    err2 += float(np.sum(cum[:, p - 1]))
        else:
            for s, x2 in zip(smats, s2):
                try:
                    mask = np.asarray(sparsity.prune_mask(
                        jnp.asarray(s), frac, group=comp.scfg.group,
                        pattern=comp.scfg.pattern))
                except ValueError:  # keep_frac above the N:M ceiling
                    ok = False
                    break
                err2 += float(np.sum(x2[~mask]))
        if ok:
            curve[float(cr)] = math.sqrt(err2)
    return curve, err_before


# ------------------------------------------------------------------
# Water-filling solver
# ------------------------------------------------------------------

def waterfill(frontiers: Sequence[Frontier], budget: float,
              floor: float = 0.0, ceiling: float = 1.0
              ) -> Dict[str, float]:
    """Allocate one CR per frontier so the size-weighted mean CR meets
    ``budget``, minimizing the summed predicted error.

    Discrete greedy water-filling: every group starts at its lowest
    admissible candidate; the step with the smallest marginal error
    increase per unit of size-weighted CR gained is taken until the
    budget is reached (ties break on the group key — deterministic).
    The uniform allocation (every group at the smallest candidate
    >= budget) is evaluated as a fallback, so the returned allocation
    is never predicted-worse than uniform. Raises ValueError when the
    budget is infeasible (every group at its ceiling still falls
    short) or a group has no admissible candidates."""
    if not frontiers:
        raise ValueError("waterfill needs at least one frontier")
    work = []
    for fr in sorted(frontiers, key=lambda f: f.key):
        sel = [(float(c), float(e)) for c, e in zip(fr.crs, fr.errs)
               if floor - 1e-12 <= c <= ceiling + 1e-12]
        if not sel:
            raise ValueError(
                f"group {fr.key!r}: no admissible CR candidates inside "
                f"[floor={floor}, ceiling={ceiling}]")
        crs = [c for c, _ in sel]
        errs = [e for _, e in sel]
        work.append((fr, crs, errs))
    total = float(sum(fr.size for fr, _, _ in work))
    idx = {fr.key: 0 for fr, _, _ in work}
    cur = sum(fr.size * crs[0] for fr, crs, _ in work) / total

    while cur + 1e-9 < budget:
        best = None
        for fr, crs, errs in work:
            i = idx[fr.key]
            if i + 1 >= len(crs):
                continue
            gain = fr.size * (crs[i + 1] - crs[i]) / total
            cost = max(errs[i + 1] - errs[i], 0.0)
            cand = (cost / gain, fr.key, gain)
            if best is None or cand[:2] < best[:2]:
                best = cand
        if best is None:
            raise ValueError(
                f"budget={budget:.3f} infeasible: every group is at its "
                f"ceiling (max achievable size-weighted CR {cur:.3f})")
        idx[best[1]] += 1
        cur += best[2]

    greedy_err = sum(errs[idx[fr.key]] for fr, _, errs in work)
    uniform = {}
    for fr, crs, errs in work:
        js = [j for j, c in enumerate(crs) if c >= budget - 1e-9]
        if not js:
            uniform = None
            break
        uniform[fr.key] = js[0]
    if uniform is not None:
        uni_err = sum(errs[uniform[fr.key]] for fr, _, errs in work)
        if uni_err < greedy_err:     # greedy is a heuristic on unequal
            idx = uniform            # step sizes; never do worse than
                                     # the uniform plan we compare to
    return {fr.key: crs[idx[fr.key]] for fr, crs, _ in work}


# ------------------------------------------------------------------
# End-to-end allocation
# ------------------------------------------------------------------

def _group_key(layer: int, path: str, granularity: str) -> str:
    if path.startswith("shared."):
        return "shared"              # one set of tied weights: one CR
    if granularity == "layer":
        return f"L{layer}"
    return f"L{layer}/{path}"


def allocate_plan(cfg, params: dict, calib=None, budget: Optional[float] = None,
                  template=None, *,
                  plan=None,
                  stats: Optional[ModelTapStats] = None,
                  candidates: Optional[Sequence[float]] = None,
                  floor: Optional[float] = None,
                  ceiling: Optional[float] = None,
                  granularity: Optional[str] = None,
                  base: SLaBConfig = SLaBConfig(),
                  progress: Optional[Callable[[str], None]] = None
                  ) -> Allocation:
    """Solve per-layer/per-path CRs meeting a global ``budget`` and emit
    a concrete ``CompressionPlan``.

    ``template`` (or a parsed ``plan``) names the methods: rules with
    the ``@auto`` flag get allocated CRs; when no rule is flagged,
    every non-skip rule WITHOUT an explicit ``cr=`` option is
    allocatable (so ``allocate_plan(cfg, params, calib, 0.5,
    "*=slab")`` just works, and a hand-pinned
    ``attn.wq=wanda@cr=0.2`` is never silently overridden). Pinned
    rules keep their own ``cr`` and are excluded from the budget.
    Plan-level ``budget=`` /
    ``floor=`` / ``ceiling=`` / ``candidates=`` / ``granularity=``
    segments supply defaults for the matching arguments.

    ``stats`` reuses a precollected ``ModelTapStats``; otherwise one
    streaming pass over ``calib`` is collected here. Either way the
    returned ``Allocation.stats`` should be handed to
    ``compress_model(stats=...)`` so no second pass ever runs.
    """
    if plan is None:
        plan = plan_lib.CompressionPlan.parse(
            template if template is not None else "*=slab", base=base)
    else:
        plan = plan_lib.CompressionPlan.parse(plan, base=base)
    ao = plan.auto_options
    budget = float(budget if budget is not None else ao.get("budget", -1))
    if budget <= 0.0 or budget >= 1.0:
        raise ValueError(f"allocate_plan needs a budget in (0, 1) — got "
                         f"{budget} (pass budget= or add a 'budget=0.5' "
                         f"plan segment)")
    floor = float(floor if floor is not None
                  else ao.get("floor", DEFAULT_FLOOR))
    ceiling = float(ceiling if ceiling is not None
                    else ao.get("ceiling", DEFAULT_CEILING))
    cand = tuple(sorted(candidates if candidates is not None
                        else ao.get("candidates", DEFAULT_CANDIDATES)))
    granularity = str(granularity if granularity is not None
                      else ao.get("granularity", "linear"))
    if granularity not in ("linear", "layer"):
        raise ValueError(f"granularity must be 'linear' or 'layer', "
                         f"got {granularity!r}")

    if stats is None:
        if calib is None:
            raise ValueError("allocate_plan needs calibration data or "
                             "precollected stats=")
        stats = collect_model_stats(cfg, params, calib, plan=plan,
                                    progress=progress)

    flagged = plan.is_auto
    groups: Dict[str, dict] = {}
    member_curves: Dict[Tuple[int, str], Dict[float, float]] = {}
    emit: List[Tuple[int, str, plan_lib.PlanRule, str]] = []
    shared_pending = bool(cfg.family == "hybrid" and cfg.attn_every
                          and "shared_attn" in params)
    for l in range(cfg.n_layers):
        shared_now = (shared_pending
                      and l % cfg.attn_every == cfg.attn_every - 1)
        tap_paths = linear_paths(cfg) + (shared_linear_paths(cfg)
                                         if shared_now else [])
        if shared_now:
            shared_pending = False
        for pth in tap_paths:
            rule = plan.matching_rule(l, pth)
            if rule is None or rule.method in plan_lib._SKIP_METHODS:
                continue
            if flagged and not rule.options.get("auto"):
                continue             # pinned rule: its cr stays as-is
            if not flagged and "cr" in rule.options:
                continue             # explicit cr= is a pin, not a hint
            comp = plan.resolve(l, pth, allow_auto=True)
            if pth.startswith("shared."):
                w = _get(params["shared_attn"], pth.split(".", 1)[1])
            else:
                leaf = _get(params["layers"], pth)
                w = None if leaf is None else leaf[l]
            if w is None:
                continue
            curve, err_b = _leaf_curve(w, stats.norms.get((l, pth)),
                                       comp.compressor, cand)
            key = _group_key(l, pth, granularity)
            g = groups.setdefault(key, {"size": 0, "curves": [],
                                        "members": [], "err_before": 0.0})
            g["size"] += int(np.asarray(w).size)
            g["curves"].append(curve)
            g["members"].append((l, pth))
            g["err_before"] += err_b
            member_curves[(l, pth)] = curve
            emit.append((l, pth, rule, key))
    if not groups:
        raise ValueError("plan matched no allocatable linears — nothing "
                         "to allocate a budget over")

    frontiers = []
    for key, g in sorted(groups.items()):
        common = sorted(set.intersection(*(set(c) for c in g["curves"])))
        if not common:
            raise ValueError(
                f"group {key!r}: members share no feasible CR candidate")
        errs = [sum(c[cr] for c in g["curves"]) for cr in common]
        frontiers.append(Frontier(key, g["size"], np.asarray(common),
                                  np.asarray(errs),
                                  tuple(g["members"]), g["err_before"]))

    crs = waterfill(frontiers, budget, floor=floor, ceiling=ceiling)

    by_key = {f.key: f for f in frontiers}
    rows: List[dict] = []
    new_rules: List[plan_lib.PlanRule] = []
    consumed = set()
    for l, pth, rule, key in emit:
        cr = crs[key]
        options = {k: v for k, v in rule.options.items() if k != "auto"}
        options["cr"] = cr
        new_rules.append(plan_lib.PlanRule(pth, rule.method, layers=l,
                                           options=options))
        consumed.add(id(rule))
        rows.append({"layer": l, "path": pth, "method": rule.method,
                     "group": key, "cr": cr,
                     "err_after": member_curves[(l, pth)][cr]})
    tail = [r for r in plan.rules if id(r) not in consumed]
    # no auto_options on the emitted plan: it is fully concrete, and a
    # surviving budget= segment would re-trigger allocation when the
    # plan is stored and reused (provenance lives in the Allocation)
    out_plan = plan_lib.CompressionPlan(new_rules + tail, base=plan.base)

    achieved = (sum(by_key[k].size * c for k, c in crs.items())
                / sum(by_key[k].size for k in crs))
    predicted = sum(
        float(f.errs[int(np.searchsorted(f.crs, crs[f.key]))])
        for f in frontiers)
    if progress:
        progress(f"allocated {len(frontiers)} CR groups at budget "
                 f"{budget:.3f} (achieved {achieved:.3f})")
    return Allocation(out_plan, stats, crs, rows, budget, achieved,
                      predicted)
