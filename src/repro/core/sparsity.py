"""Sparsification primitives: comparison-group top-k and N:M semi-structured masks.

The paper (SLaB §II-B2) prunes by comparing scores inside *comparison
groups* of shape ``(g_rows, g_cols)``; the default is ``(1, D_in)`` (one
group per output row), keeping ``floor(k / D_out)`` entries per group.
Semi-structured patterns (2:4 / 4:8) are applied first, then group-wise
pruning refines down to the target sparsity (§II-B2).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def _exact_topk_mask_rows(scores2d: Array, k: int) -> Array:
    """Exact top-k mask per row of a 2-D score array (ties broken by index)."""
    n_groups, gsz = scores2d.shape
    if k <= 0:
        return jnp.zeros_like(scores2d, dtype=jnp.bool_)
    if k >= gsz:
        return jnp.ones_like(scores2d, dtype=jnp.bool_)
    _, idx = jax.lax.top_k(scores2d, k)  # (n_groups, k)
    rows = jnp.arange(n_groups, dtype=jnp.int32)[:, None]
    mask = jnp.zeros(scores2d.shape, dtype=jnp.bool_)
    return mask.at[rows, idx].set(True)


def group_topk_mask(scores: Array, keep_frac: float, group: Tuple[int, int] = (1, 0)) -> Array:
    """Keep the top ``floor(keep_frac * group_size)`` scores inside each group.

    ``group=(g_rows, g_cols)``; ``0`` means "the full extent of that dim".
    Groups tile the matrix; both dims must divide evenly (all paper group
    settings do: (1, D_in), (1, D_in/32), (16, D_in), ...).
    """
    d_out, d_in = scores.shape
    g_rows = group[0] or d_out
    g_cols = group[1] or d_in
    if d_out % g_rows or d_in % g_cols:
        # paper models always tile; odd smoke geometries (e.g. d_ff=344
        # with a (16, D_in) group) shrink to the nearest divisor
        g_rows = math.gcd(g_rows, d_out)
        g_cols = math.gcd(g_cols, d_in)
    gsz = g_rows * g_cols
    k = int(math.floor(keep_frac * gsz))
    # (Do/gr, gr, Di/gc, gc) -> (Do/gr, Di/gc, gr, gc) -> (n_groups, gsz)
    s = scores.reshape(d_out // g_rows, g_rows, d_in // g_cols, g_cols)
    s = s.transpose(0, 2, 1, 3).reshape(-1, gsz)
    m = _exact_topk_mask_rows(s, k)
    m = m.reshape(d_out // g_rows, d_in // g_cols, g_rows, g_cols)
    return m.transpose(0, 2, 1, 3).reshape(d_out, d_in)


def nm_mask(scores: Array, n: int, m: int) -> Array:
    """N:M semi-structured mask: keep the n best of every m consecutive
    elements along the input (last) dimension."""
    d_out, d_in = scores.shape
    if d_in % m:
        raise ValueError(f"D_in={d_in} not divisible by m={m}")
    s = scores.reshape(d_out * (d_in // m), m)
    mask = _exact_topk_mask_rows(s, n)
    return mask.reshape(d_out, d_in)


def parse_pattern(pattern: str) -> Tuple[int, int]:
    n, m = pattern.split(":")
    return int(n), int(m)


def prune_mask(
    scores: Array,
    keep_frac: float,
    group: Tuple[int, int] = (1, 0),
    pattern: Optional[str] = None,
) -> Array:
    """Full paper semantics: optional N:M pre-mask, then group top-k among
    survivors (pruned entries get a -inf score so they are never re-kept)."""
    scores = scores.astype(jnp.float32)
    if pattern is not None:
        n, m = parse_pattern(pattern)
        if keep_frac > n / m + 1e-9:
            raise ValueError(
                f"keep_frac={keep_frac:.4f} exceeds the {pattern} ceiling {n}/{m}"
            )
        pre = nm_mask(scores, n, m)
        scores = jnp.where(pre, scores, -jnp.inf)
    return group_topk_mask(scores, keep_frac, group)


def mask_nnz_per_row_uniform(mask: Array) -> Optional[int]:
    """If every row has the same nnz (true for (1, D_in) comparison groups),
    return it; else None. Used to decide ELL packability."""
    nnz = jnp.sum(mask, axis=1)
    first = int(nnz[0])
    return first if bool(jnp.all(nnz == first)) else None
