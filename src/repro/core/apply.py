"""Forward ops for SLaB-compressed linear layers (pure-jnp paths).

The rank-1 Hadamard structure gives the cheap serving identity

    x @ (u vᵀ ⊙ B)ᵀ = ((x ⊙ v) @ Bᵀ) ⊙ u

so a compressed linear needs one sparse matmul + one binary matmul + two
vector scalings. The Pallas kernels in ``repro.kernels`` implement the
packed/tiled versions; these jnp forms are the oracles and the XLA
fallback used by the serving path when kernels are disabled.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.packing import ELLPacked, NMPacked, SLaBPacked, unpack_nm, unpack_sign_bits
from repro.core.slab import SLaBDecomposition, low_rank_times_binary

Array = jax.Array


def slab_linear(x: Array, dec: SLaBDecomposition) -> Array:
    """y = x @ (W_S + W_L ⊙ W_B)ᵀ for x (..., D_in).

    Uses the rank-1 fast path when possible; general ranks and ablation
    variants fall back to materializing W_L ⊙ W_B.
    """
    dt = x.dtype
    w_s = dec.w_s.astype(dt)
    y = x @ w_s.T
    has_lr = dec.u is not None and dec.u.size
    has_b = dec.w_b is not None and dec.w_b.size
    if has_lr and has_b and dec.u.shape[-1] == 1:
        u = dec.u[:, 0].astype(dt)
        v = dec.v[:, 0].astype(dt)
        y = y + ((x * v) @ dec.w_b.T.astype(dt)) * u
    elif has_lr or has_b:
        y = y + x @ low_rank_times_binary(dec).astype(dt).T
    return y


def slab_linear_packed(x: Array, p: SLaBPacked) -> Array:
    """Forward from the packed (serving) format — jnp reference path that
    unpacks on the fly; the Pallas kernel does the same tile-wise in VMEM."""
    dt = x.dtype
    if isinstance(p.sparse, NMPacked):
        w_s = unpack_nm(p.sparse)
    elif isinstance(p.sparse, ELLPacked):
        from repro.core.packing import ell_unpack
        w_s = ell_unpack(p.sparse)
    else:
        w_s = p.sparse
    b = unpack_sign_bits(p.b_packed, p.d_in, dtype=dt)
    y = x @ w_s.astype(dt).T
    return y + ((x * p.v.astype(dt)) @ b.T) * p.u.astype(dt)


class DenseEquivalent(NamedTuple):
    w: Array


def to_dense(dec: SLaBDecomposition, dtype=jnp.bfloat16) -> Array:
    """Materialize Ŵ (used to swap compressed weights into existing model
    params for evaluation; memory-equal but numerics-equal to slab_linear)."""
    from repro.core.slab import reconstruct
    return reconstruct(dec).astype(dtype)
