"""Storage packing for SLaB components — the formats the Pallas kernels
stream from HBM.

- sign bits:   W_B {±1} -> uint32 words, 32 signs/word along D_in
               (16x smaller than bf16; bit j of word g is column g*32+j).
- N:M packed:  W_S (2:4 / 4:8) -> values (Do, Di*n/m) + int8 indices
               (position of each kept element inside its m-group).
- ELL packed:  unstructured W_S -> row-padded values (Do, K_max) +
               uint16 column indices (uint32 when D_in > 65535),
               K_max = realized max per-row nnz (short rows pad with
               value 0 at a zero column).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


# ------------------------------ sign bits ------------------------------

def pack_sign_bits(w_b: Array) -> Array:
    """Pack ±1 (or bool 'is positive') into uint32 along the last dim.

    D_in must be a multiple of 32 (true for every assigned architecture).
    """
    d_out, d_in = w_b.shape
    if d_in % 32:
        raise ValueError(f"D_in={d_in} not a multiple of 32")
    pos = (w_b > 0).astype(jnp.uint32).reshape(d_out, d_in // 32, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(pos << shifts[None, None, :], axis=-1).astype(jnp.uint32)


def unpack_sign_bits(packed: Array, d_in: int, dtype=jnp.int8) -> Array:
    """Inverse of pack_sign_bits: uint32 words -> ±1 matrix (Do, d_in)."""
    d_out, words = packed.shape
    if words * 32 != d_in:
        raise ValueError(f"{words} words cannot hold D_in={d_in}")
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (packed[:, :, None] >> shifts[None, None, :]) & jnp.uint32(1)
    pm = bits.astype(jnp.int32) * 2 - 1
    return pm.reshape(d_out, d_in).astype(dtype)


# ------------------------------ N:M packing ----------------------------

class NMPacked(NamedTuple):
    values: Array   # (Do, Di // m, n)
    indices: Array  # (Do, Di // m, n) int8, position within the m-group
    n: int
    m: int
    d_in: int


def pack_nm(w_s: Array, n: int, m: int, strict: bool = False) -> NMPacked:
    """Pack an N:M-sparse dense-masked matrix. Rows whose group has fewer
    than n non-zeros are padded with (value 0, index = smallest unused).

    ``strict=True`` raises if any m-group holds MORE than n non-zeros
    (the pack would silently drop values) — the guard the plan-driven
    packer uses against a rule pattern that disagrees with what the
    compressor actually produced."""
    d_out, d_in = w_s.shape
    if d_in % m:
        raise ValueError(f"D_in={d_in} not divisible by m={m}")
    g = w_s.reshape(d_out, d_in // m, m)
    nz = (g != 0)
    if strict:
        worst = int(jnp.max(jnp.sum(nz, axis=-1)))
        if worst > n:
            raise ValueError(
                f"matrix is not {n}:{m} sparse (a group holds {worst} "
                f"non-zeros; packing would drop values)")
    # Order: non-zeros first (stable by position), then zeros.
    order_key = jnp.where(nz, jnp.arange(m)[None, None, :], m + jnp.arange(m)[None, None, :])
    idx = jnp.argsort(order_key, axis=-1)[..., :n].astype(jnp.int8)
    vals = jnp.take_along_axis(g, idx.astype(jnp.int32), axis=-1)
    return NMPacked(vals.astype(w_s.dtype), idx, n, m, d_in)


def unpack_nm(p: NMPacked) -> Array:
    d_out = p.values.shape[0]
    rows = jnp.arange(d_out)[:, None, None]
    grps = jnp.arange(p.d_in // p.m)[None, :, None]
    g = jnp.zeros((d_out, p.d_in // p.m, p.m), p.values.dtype)
    g = g.at[rows, grps, p.indices.astype(jnp.int32)].add(p.values)
    return g.reshape(d_out, p.d_in)


def nm_packed_bits(p: NMPacked, bits: int = 16) -> int:
    """Storage cost: values at b bits + ceil(log2(m)) bits per index."""
    import math
    idx_bits = max(1, math.ceil(math.log2(p.m)))
    return p.values.size * bits + p.indices.size * idx_bits


# ------------------------------ ELL packing ----------------------------

class ELLPacked(NamedTuple):
    values: Array   # (Do, K_max)
    indices: Array  # (Do, K_max) column ids: uint16 (2 bytes — the reason
    d_in: int       # ELL beats dense bytes at 50% unstructured sparsity),
                    # widened to uint32 when D_in overflows 16 bits.


def ell_row_nnz_max(w_s: Array) -> int:
    """Realized K_max of a sparse matrix: the largest per-row nnz (the
    ELL pad width). Device sync — pack-time only."""
    return max(1, int(jnp.max(jnp.sum(w_s != 0, axis=1))))


_ELL_MAX_DIN = 2 ** 16   # uint16 column-id ceiling; wider rows use uint32


def ell_idx_itemsize(d_in: int) -> int:
    """Bytes per ELL column index: 2 (uint16) while indices fit 16 bits,
    4 (uint32) for wider linears (e.g. nemotron_4_340b d_ff)."""
    return 2 if d_in <= _ELL_MAX_DIN else 4


def ell_wins_bytes(k_max: int, d_in: int, itemsize: int = 4) -> bool:
    """True when row-padded ELL (values at ``itemsize`` bytes + uint16 or
    uint32 indices, whichever D_in requires) stores strictly fewer bytes
    than the dense matrix."""
    return k_max * (itemsize + ell_idx_itemsize(d_in)) < d_in * itemsize


def ell_pack(w_s: Array, nnz: int | None = None) -> ELLPacked:
    """Row-padded ELL: keep each row's ``nnz`` largest-magnitude entries
    (default: the realized per-row max, so nothing is dropped). Short
    rows pad with (value 0, index of some zero column). Column indices
    are uint16, widened to uint32 when D_in > 65535 (they would wrap)."""
    d_out, d_in = w_s.shape
    idx_dtype = jnp.uint16 if d_in <= _ELL_MAX_DIN else jnp.uint32
    if nnz is None:
        nnz = ell_row_nnz_max(w_s)
    keys = jnp.where(w_s != 0, -jnp.abs(w_s.astype(jnp.float32)), jnp.inf)
    idx = jnp.argsort(keys, axis=1)[:, :nnz].astype(jnp.int32)
    idx = jnp.sort(idx, axis=1)
    vals = jnp.take_along_axis(w_s, idx, axis=1)
    return ELLPacked(vals, idx.astype(idx_dtype), d_in)


def ell_unpack(p: ELLPacked) -> Array:
    d_out, nnz = p.values.shape
    rows = jnp.arange(d_out)[:, None]
    out = jnp.zeros((d_out, p.d_in), p.values.dtype)
    return out.at[rows, p.indices.astype(jnp.int32)].add(p.values)


# --------------------------- SLaB packed bundle ------------------------

class SLaBPacked(NamedTuple):
    """On-HBM serving format of one compressed linear layer."""
    sparse: NMPacked | ELLPacked | Array  # dense-masked fallback is a raw Array
    u: Array
    v: Array
    b_packed: Array  # uint32 (Do, Di/32)
    d_out: int
    d_in: int


def pack_decomposition(dec, pattern: str | None = None) -> SLaBPacked:
    from repro.core import sparsity as sp
    d_out, d_in = dec.w_s.shape
    if pattern is not None:
        n, m = sp.parse_pattern(pattern)
        sparse = pack_nm(dec.w_s, n, m)
    else:
        nnz = sp.mask_nnz_per_row_uniform(dec.w_s != 0)
        sparse = ell_pack(dec.w_s, nnz) if nnz is not None else dec.w_s
    u = dec.u[:, 0] if dec.u.ndim == 2 and dec.u.shape[1] == 1 else dec.u
    v = dec.v[:, 0] if dec.v.ndim == 2 and dec.v.shape[1] == 1 else dec.v
    return SLaBPacked(sparse, u, v, pack_sign_bits(dec.w_b), d_out, d_in)
