"""CompressionPlan: per-linear compression policy as ordered glob rules.

The paper's quality comes from activation-aware, *per-layer* decisions;
a plan makes that a first-class object instead of one global
``method=`` string. A plan is an ordered rule list; each rule matches
``linear_paths`` names (glob) plus an optional layer range, and resolves
to a registered compressor with per-rule hyper-parameters. First match
wins; unmatched linears stay dense.

Spec formats (``CompressionPlan.parse`` accepts all of them):

inline DSL — ``;``-separated ``[layers/]pattern=method[@k=v,...]``::

    attn.*=sparsegpt; moe.shared.*=slab@cr=0.4; mamba.out=skip; *=slab
    0-3/mlp.*=wanda@pattern=2:4; *=slab        # layers 0..3 only

JSON — a list of rule objects (or ``{"base": {...}, "rules": [...]}``;
loose keys are per-rule options)::

    [{"match": "attn.*", "method": "sparsegpt", "layers": "0-3"},
     {"match": "*", "method": "slab", "cr": 0.4, "pattern": "2:4"}]

``@/path/to/plan.json`` loads the JSON from a file. Layer ranges:
``"2"``, ``"0-3"``, ``"5-"`` (open end), ``"-2"``, comma-separated
unions. Option values are JSON literals where possible (``cr=0.4`` →
float), bare strings otherwise (``pattern=2:4``). Options naming
``SLaBConfig`` fields override the plan's base config; anything else is
forwarded to the compressor's constructor (e.g. ``alt_iters`` for
``hassle``).

``CalibrationSpec`` rides along: it wraps the calibration token array
with a streaming chunk size, so the pipeline can drive ``TapCapture``'s
cross-``record`` accumulation with many calibration batches without
materializing one giant forward.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import compressor as compressor_lib
from repro.core.slab import SLaBConfig

_SKIP_METHODS = ("skip", "none")
_SCFG_FIELDS = {f.name for f in dataclasses.fields(SLaBConfig)}


@functools.lru_cache(maxsize=256)
def _parse_layer_spec(spec: str) -> Tuple[Tuple[int, Optional[int]], ...]:
    """``"0-3,7,12-"`` -> ((0, 3), (7, 7), (12, None)) inclusive ranges."""
    out: List[Tuple[int, Optional[int]]] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "-" in part:
            lo_s, hi_s = part.split("-", 1)
            lo = int(lo_s) if lo_s.strip() else 0
            hi = int(hi_s) if hi_s.strip() else None
            out.append((lo, hi))
        else:
            v = int(part)
            out.append((v, v))
    if not out:
        raise ValueError(f"empty layer spec {spec!r}")
    return tuple(out)


def _layers_match(layers, layer: int) -> bool:
    if layers is None:
        return True
    if isinstance(layers, int):
        return layer == layers
    if isinstance(layers, (list, tuple)):
        return layer in layers
    return any(lo <= layer and (hi is None or layer <= hi)
               for lo, hi in _parse_layer_spec(str(layers)))


def _coerce(v: str) -> Any:
    try:
        return json.loads(v)
    except (json.JSONDecodeError, ValueError):
        return v


@dataclasses.dataclass
class PlanRule:
    """One policy rule: glob over linear-path names + layer range ->
    compressor name + per-rule options."""

    match: str                                # glob, e.g. "attn.*"
    method: str                               # registry name or "skip"
    layers: Union[str, int, Sequence[int], None] = None
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def matches(self, layer: int, path: str) -> bool:
        return (fnmatch.fnmatchcase(path, self.match)
                and _layers_match(self.layers, layer))


@dataclasses.dataclass(frozen=True)
class ResolvedCompression:
    """What a plan hands the pipeline for one (layer, path)."""

    method: str
    compressor: compressor_lib.Compressor

    @property
    def needs(self):
        return self.compressor.needs

    @property
    def scfg(self) -> SLaBConfig:
        return self.compressor.scfg


class CompressionPlan:
    """Ordered rules; ``resolve`` is first-match-wins."""

    def __init__(self, rules: Sequence[PlanRule],
                 base: SLaBConfig = SLaBConfig()):
        self.rules = list(rules)
        self.base = base
        self._built: Dict[int, ResolvedCompression] = {}

    def resolve(self, layer: int, path: str
                ) -> Optional[ResolvedCompression]:
        """Compressor for (layer, path); None = leave dense (an explicit
        ``skip`` rule or no matching rule at all)."""
        for i, rule in enumerate(self.rules):
            if not rule.matches(layer, path):
                continue
            if rule.method in _SKIP_METHODS:
                return None
            if i not in self._built:
                self._built[i] = self._build(rule)
            return self._built[i]
        return None

    def _build(self, rule: PlanRule) -> ResolvedCompression:
        over = {k: v for k, v in rule.options.items() if k in _SCFG_FIELDS}
        extra = {k: v for k, v in rule.options.items()
                 if k not in _SCFG_FIELDS}
        if isinstance(over.get("group"), list):
            over["group"] = tuple(over["group"])
        scfg = dataclasses.replace(self.base, **over)
        return ResolvedCompression(
            rule.method, compressor_lib.get(rule.method, scfg, **extra))

    def __repr__(self) -> str:
        rs = "; ".join(
            (f"{r.layers}/" if r.layers is not None else "")
            + f"{r.match}={r.method}"
            + ("@" + ",".join(f"{k}={v}" for k, v in r.options.items())
               if r.options else "")
            for r in self.rules)
        return f"CompressionPlan({rs})"

    # -- parsing -----------------------------------------------------

    @classmethod
    def parse(cls, spec, base: SLaBConfig = SLaBConfig()
              ) -> "CompressionPlan":
        if isinstance(spec, CompressionPlan):
            return spec
        if isinstance(spec, PlanRule):
            return cls([spec], base)
        if isinstance(spec, str):
            s = spec.strip()
            if s.startswith("@"):
                with open(s[1:]) as f:
                    spec = json.load(f)
            else:
                parsed = None
                if s and s[0] in "{[":
                    # looks like JSON — but a DSL rule may also start
                    # with a fnmatch character class ("[am]*.out=skip"),
                    # so fall back to the DSL on a parse failure
                    try:
                        parsed = json.loads(s)
                    except json.JSONDecodeError:
                        parsed = None
                spec = (parsed if parsed is not None
                        else [_parse_inline_rule(r)
                              for r in s.split(";") if r.strip()])
        if isinstance(spec, dict):
            if "method" in spec:               # a bare single-rule object
                spec = [spec]
            else:
                bover = {k: v for k, v in spec.get("base", {}).items()
                         if k in _SCFG_FIELDS}
                if isinstance(bover.get("group"), list):
                    bover["group"] = tuple(bover["group"])
                base = dataclasses.replace(base, **bover)
                spec = spec.get("rules", [])
        if isinstance(spec, (list, tuple)):
            rules = [r if isinstance(r, PlanRule) else _rule_from_dict(r)
                     for r in spec]
            if not rules:
                raise ValueError(
                    "CompressionPlan spec resolved to zero rules — a "
                    "plan that compresses nothing is almost certainly a "
                    "spec mistake (use '*=skip' to skip everything)")
            return cls(rules, base)
        raise TypeError(f"cannot parse a CompressionPlan from "
                        f"{type(spec).__name__}")


def _split_top_level(s: str, sep: str) -> List[str]:
    """Split on ``sep`` outside []/{}/() nesting, so JSON-literal option
    values like ``group=[4,1]`` survive the comma split."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def _parse_inline_rule(txt: str) -> PlanRule:
    txt = txt.strip()
    layers = None
    # a "/" is the layer-range separator only before the first "=" —
    # option *values* may legitimately contain slashes (paths etc.)
    slash, eq = txt.find("/"), txt.find("=")
    if slash != -1 and (eq == -1 or slash < eq):
        layers, txt = txt.split("/", 1)
        layers = layers.strip()
    if "=" not in txt:
        raise ValueError(f"bad plan rule {txt!r}: expected "
                         f"[layers/]pattern=method[@k=v,...]")
    match, rhs = txt.split("=", 1)
    method, _, opts = rhs.partition("@")
    options: Dict[str, Any] = {}
    for kv in filter(None, (p.strip() for p in _split_top_level(opts, ","))):
        if "=" not in kv:
            raise ValueError(f"bad option {kv!r} in plan rule {txt!r}")
        k, v = kv.split("=", 1)
        options[k.strip()] = _coerce(v.strip())
    return PlanRule(match.strip(), method.strip(), layers, options)


def _rule_from_dict(d: dict) -> PlanRule:
    d = dict(d)
    match = d.pop("match")
    method = d.pop("method")
    layers = d.pop("layers", None)
    options = dict(d.pop("options", {}))
    options.update(d)                      # loose keys are options
    return PlanRule(match, method, layers, options)


def plan_for_method(method: str, scfg: SLaBConfig = SLaBConfig()
                    ) -> CompressionPlan:
    """The ``method=`` sugar: one catch-all rule."""
    return CompressionPlan([PlanRule("*", method)], base=scfg)


# ------------------------------------------------------------------
# Streaming calibration
# ------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationSpec:
    """Calibration data + streaming policy.

    ``tokens`` is (N, S) int32 ids (or (N, S, D) embeds for
    stub-frontend families). ``batch_size`` sequences are forwarded per
    chunk; tap statistics accumulate across chunks inside one
    ``TapCapture``, so N can exceed what a single forward fits. None
    keeps the single-batch behavior.
    """

    tokens: Any
    batch_size: Optional[int] = None

    def batches(self) -> List[np.ndarray]:
        t = np.asarray(self.tokens)
        bs = self.batch_size or t.shape[0]
        if bs <= 0:
            raise ValueError(f"batch_size must be positive, got {bs}")
        return [t[i:i + bs] for i in range(0, t.shape[0], bs)]
