"""CompressionPlan: per-linear compression policy as ordered glob rules.

The paper's quality comes from activation-aware, *per-layer* decisions;
a plan makes that a first-class object instead of one global
``method=`` string. A plan is an ordered rule list; each rule matches
``linear_paths`` names (glob) plus an optional layer range, and resolves
to a registered compressor with per-rule hyper-parameters. First match
wins; unmatched linears stay dense.

Spec formats (``CompressionPlan.parse`` accepts all of them):

inline DSL — ``;``-separated ``[layers/]pattern=method[@k=v,...]``::

    attn.*=sparsegpt; moe.shared.*=slab@cr=0.4; mamba.out=skip; *=slab
    0-3/mlp.*=wanda@pattern=2:4; *=slab        # layers 0..3 only

JSON — a list of rule objects (or ``{"base": {...}, "rules": [...]}``;
loose keys are per-rule options)::

    [{"match": "attn.*", "method": "sparsegpt", "layers": "0-3"},
     {"match": "*", "method": "slab", "cr": 0.4, "pattern": "2:4"}]

``@/path/to/plan.json`` loads the JSON from a file. Layer ranges:
``"2"``, ``"0-3"``, ``"5-"`` (open end), ``"-2"``, comma-separated
unions. Option values are JSON literals where possible (``cr=0.4`` →
float), bare strings otherwise (``pattern=2:4``); a bare word
(``auto``) is a True flag. Options naming ``SLaBConfig`` fields
override the plan's base config; anything else is forwarded to the
compressor's constructor (e.g. ``alt_iters`` for ``hassle``).

**Auto-allocated CRs** — a rule whose options carry the ``auto`` flag
leaves its ``cr`` to the sensitivity-driven budget allocator
(``core.allocator``); plan-level allocator options ride as bare
``key=value`` segments (keys: ``budget`` / ``floor`` / ``ceiling`` /
``candidates`` / ``granularity``)::

    *=slab@auto; budget=0.5
    attn.*=sparsegpt; *=slab@auto,iters=4; budget=0.6; ceiling=0.9

Such a plan cannot be resolved directly (``resolve`` raises); the
pipeline routes it through ``core.allocator.allocate_plan`` which
returns a concrete plan with per-(layer, path) ``cr`` rules.

Plans round-trip: ``parse(plan.to_dsl())``, ``parse(plan.to_json())``
and ``parse(repr(plan))`` all reproduce an equal plan (string option
values must not contain ``,``/``;``, which the DSL reserves).

``CalibrationSpec`` rides along: it wraps the calibration token array
with a streaming chunk size, so the pipeline can drive ``TapCapture``'s
cross-``record`` accumulation with many calibration batches without
materializing one giant forward.
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import compressor as compressor_lib
from repro.core.slab import SLaBConfig

_SKIP_METHODS = ("skip", "none")
_SCFG_FIELDS = {f.name for f in dataclasses.fields(SLaBConfig)}
# plan-level allocator options: bare "key=value" DSL segments / loose
# JSON keys consumed by core.allocator.allocate_plan
_AUTO_KEYS = ("budget", "floor", "ceiling", "candidates", "granularity")


@functools.lru_cache(maxsize=256)
def _parse_layer_spec(spec: str) -> Tuple[Tuple[int, Optional[int]], ...]:
    """``"0-3,7,12-"`` -> ((0, 3), (7, 7), (12, None)) inclusive ranges."""
    out: List[Tuple[int, Optional[int]]] = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        if "-" in part:
            lo_s, hi_s = part.split("-", 1)
            lo = int(lo_s) if lo_s.strip() else 0
            hi = int(hi_s) if hi_s.strip() else None
            out.append((lo, hi))
        else:
            v = int(part)
            out.append((v, v))
    if not out:
        raise ValueError(f"empty layer spec {spec!r}")
    return tuple(out)


def _layers_match(layers, layer: int) -> bool:
    if layers is None:
        return True
    if isinstance(layers, int):
        return layer == layers
    if isinstance(layers, (list, tuple)):
        return layer in layers
    return any(lo <= layer and (hi is None or layer <= hi)
               for lo, hi in _parse_layer_spec(str(layers)))


def _coerce(v: str) -> Any:
    try:
        return json.loads(v)
    except (json.JSONDecodeError, ValueError):
        return v


@dataclasses.dataclass
class PlanRule:
    """One policy rule: glob over linear-path names + layer range ->
    compressor name + per-rule options."""

    match: str                                # glob, e.g. "attn.*"
    method: str                               # registry name or "skip"
    layers: Union[str, int, Sequence[int], None] = None
    options: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        # normalize int / int-list layer specs to the DSL string form
        # so equality and to_dsl/repr round-trips hold for every
        # construction route (5 == parse("5/...") layers)
        if isinstance(self.layers, int):
            self.layers = str(self.layers)
        elif isinstance(self.layers, (list, tuple)):
            self.layers = ",".join(str(x) for x in self.layers)

    def matches(self, layer: int, path: str) -> bool:
        return (fnmatch.fnmatchcase(path, self.match)
                and _layers_match(self.layers, layer))


@dataclasses.dataclass(frozen=True)
class ResolvedCompression:
    """What a plan hands the pipeline for one (layer, path)."""

    method: str
    compressor: compressor_lib.Compressor

    @property
    def needs(self):
        return self.compressor.needs

    @property
    def scfg(self) -> SLaBConfig:
        return self.compressor.scfg


class CompressionPlan:
    """Ordered rules; ``resolve`` is first-match-wins."""

    def __init__(self, rules: Sequence[PlanRule],
                 base: SLaBConfig = SLaBConfig(),
                 auto_options: Optional[Dict[str, Any]] = None):
        self.rules = list(rules)
        self.base = base
        self.auto_options = dict(auto_options or {})
        self._built: Dict[int, ResolvedCompression] = {}

    @property
    def is_auto(self) -> bool:
        """True while any rule still needs the budget allocator to pin
        its CR (the ``@auto`` flag)."""
        return any(r.options.get("auto") for r in self.rules)

    @property
    def wants_allocation(self) -> bool:
        """True when the pipeline should route this plan through the
        budget allocator: any ``@auto`` rule, or a plan-level
        ``budget=`` with at least one allocatable rule (non-skip, no
        explicit ``cr=`` pin). The latter keeps ``'*=slab; budget=0.5'``
        honest — a budget segment is never silently dropped — while
        allocator-emitted plans (every rule pinned by ``cr=``) stay
        concrete."""
        if self.is_auto:
            return True
        if self.auto_options.get("budget") is None:
            return False
        return any(r.method not in _SKIP_METHODS and "cr" not in r.options
                   for r in self.rules)

    def matching_rule(self, layer: int, path: str) -> Optional[PlanRule]:
        """The first rule matching (layer, path), skip rules included."""
        for rule in self.rules:
            if rule.matches(layer, path):
                return rule
        return None

    def resolve(self, layer: int, path: str, allow_auto: bool = False
                ) -> Optional[ResolvedCompression]:
        """Compressor for (layer, path); None = leave dense (an explicit
        ``skip`` rule or no matching rule at all). ``allow_auto`` builds
        ``@auto`` rules at the base config's CR (probe-only use — the
        allocator reads ``needs``/``keep_fraction_for`` this way)."""
        for i, rule in enumerate(self.rules):
            if not rule.matches(layer, path):
                continue
            if rule.method in _SKIP_METHODS:
                return None
            if rule.options.get("auto") and not allow_auto:
                raise ValueError(
                    f"plan rule {rule.match!r} is @auto: its CR is not "
                    f"allocated yet — run core.allocator.allocate_plan "
                    f"(or give the plan a 'budget=' segment and let the "
                    f"pipeline allocate)")
            if i not in self._built:
                self._built[i] = self._build(rule)
            return self._built[i]
        return None

    def _build(self, rule: PlanRule) -> ResolvedCompression:
        over = {k: v for k, v in rule.options.items() if k in _SCFG_FIELDS}
        extra = {k: v for k, v in rule.options.items()
                 if k not in _SCFG_FIELDS and k != "auto"}
        if isinstance(over.get("group"), list):
            over["group"] = tuple(over["group"])
        scfg = dataclasses.replace(self.base, **over)
        return ResolvedCompression(
            rule.method, compressor_lib.get(rule.method, scfg, **extra))

    # -- serialization (round-trips through parse) --------------------

    def to_dsl(self) -> str:
        """The inline-DSL form; ``parse(plan.to_dsl())`` == ``plan``."""
        segs = [f"{k}={_fmt_opt(v)}" for k, v in self.auto_options.items()]
        segs += [_rule_to_dsl(r) for r in self.rules]
        return "; ".join(segs)

    def to_json(self) -> str:
        """The JSON-dict form; ``parse(plan.to_json())`` == ``plan``."""
        obj: Dict[str, Any] = {}
        bover = {f.name: getattr(self.base, f.name)
                 for f in dataclasses.fields(SLaBConfig)
                 if getattr(self.base, f.name) != f.default}
        if bover:
            obj["base"] = {k: list(v) if isinstance(v, tuple) else v
                           for k, v in bover.items()}
        obj.update(self.auto_options)
        rules = []
        for r in self.rules:
            d: Dict[str, Any] = {"match": r.match, "method": r.method}
            if r.layers is not None:
                d["layers"] = r.layers         # normalized str form
            if r.options:
                d["options"] = dict(r.options)
            rules.append(d)
        obj["rules"] = rules
        return json.dumps(obj)

    def __eq__(self, other) -> bool:
        return (isinstance(other, CompressionPlan)
                and self.rules == other.rules
                and self.base == other.base
                and self.auto_options == other.auto_options)

    def __repr__(self) -> str:
        return f"CompressionPlan({self.to_dsl()})"

    # -- parsing -----------------------------------------------------

    @classmethod
    def parse(cls, spec, base: SLaBConfig = SLaBConfig()
              ) -> "CompressionPlan":
        if isinstance(spec, CompressionPlan):
            return spec
        if isinstance(spec, PlanRule):
            return cls([spec], base)
        auto_options: Dict[str, Any] = {}
        if isinstance(spec, str):
            s = spec.strip()
            if s.startswith("CompressionPlan(") and s.endswith(")"):
                s = s[len("CompressionPlan("):-1].strip()  # repr round-trip
            if s.startswith("@"):
                with open(s[1:]) as f:
                    spec = json.load(f)
            else:
                parsed = None
                if s and s[0] in "{[":
                    # looks like JSON — but a DSL rule may also start
                    # with a fnmatch character class ("[am]*.out=skip"),
                    # so fall back to the DSL on a parse failure
                    try:
                        parsed = json.loads(s)
                    except json.JSONDecodeError:
                        parsed = None
                if parsed is not None:
                    spec = parsed
                else:
                    rules: List[PlanRule] = []
                    for seg in s.split(";"):
                        seg = seg.strip()
                        if not seg:
                            continue
                        k, eq, v = seg.partition("=")
                        if eq and k.strip() in _AUTO_KEYS and "/" not in k:
                            auto_options[k.strip()] = _coerce(v.strip())
                        else:
                            rules.append(_parse_inline_rule(seg))
                    spec = rules
        if isinstance(spec, dict):
            if "method" in spec:               # a bare single-rule object
                spec = [spec]
            else:
                spec = dict(spec)
                for k in [k for k in spec if k in _AUTO_KEYS]:
                    auto_options[k] = spec.pop(k)
                bover = {k: v for k, v in spec.get("base", {}).items()
                         if k in _SCFG_FIELDS}
                if isinstance(bover.get("group"), list):
                    bover["group"] = tuple(bover["group"])
                base = dataclasses.replace(base, **bover)
                spec = spec.get("rules", [])
        if isinstance(spec, (list, tuple)):
            rules = [r if isinstance(r, PlanRule) else _rule_from_dict(r)
                     for r in spec]
            if not rules:
                raise ValueError(
                    "CompressionPlan spec resolved to zero rules — a "
                    "plan that compresses nothing is almost certainly a "
                    "spec mistake (use '*=skip' to skip everything)")
            return cls(rules, base, auto_options)
        raise TypeError(f"cannot parse a CompressionPlan from "
                        f"{type(spec).__name__}")


def _split_top_level(s: str, sep: str) -> List[str]:
    """Split on ``sep`` outside []/{}/() nesting, so JSON-literal option
    values like ``group=[4,1]`` survive the comma split."""
    parts: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in s:
        if ch in "[{(":
            depth += 1
        elif ch in ")}]":
            depth -= 1
        if ch == sep and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    parts.append("".join(cur))
    return parts


def _parse_inline_rule(txt: str) -> PlanRule:
    txt = txt.strip()
    layers = None
    # a "/" is the layer-range separator only before the first "=" —
    # option *values* may legitimately contain slashes (paths etc.)
    slash, eq = txt.find("/"), txt.find("=")
    if slash != -1 and (eq == -1 or slash < eq):
        layers, txt = txt.split("/", 1)
        layers = layers.strip()
    if "=" not in txt:
        raise ValueError(f"bad plan rule {txt!r}: expected "
                         f"[layers/]pattern=method[@k=v,...]")
    match, rhs = txt.split("=", 1)
    method, _, opts = rhs.partition("@")
    options: Dict[str, Any] = {}
    for kv in filter(None, (p.strip() for p in _split_top_level(opts, ","))):
        if "=" in kv:
            k, v = kv.split("=", 1)
            options[k.strip()] = _coerce(v.strip())
        elif kv == "auto":                     # the only bare flag —
            options[kv] = True                 # anything else is a typo
        else:
            raise ValueError(f"bad option {kv!r} in plan rule {txt!r} "
                             f"(expected k=v; the only bare flag is "
                             f"'auto')")
    return PlanRule(match.strip(), method.strip(), layers, options)


def _fmt_opt(v: Any) -> str:
    """Option value in DSL form: bare strings stay bare, everything else
    is a JSON literal (so ``_coerce`` recovers the same value)."""
    return v if isinstance(v, str) else json.dumps(v)


def _rule_to_dsl(r: PlanRule) -> str:
    layers = f"{r.layers}/" if r.layers is not None else ""
    opts = ",".join(k if (v is True and k == "auto")
                    else f"{k}={_fmt_opt(v)}"
                    for k, v in r.options.items())
    return f"{layers}{r.match}={r.method}" + (f"@{opts}" if opts else "")


def _rule_from_dict(d: dict) -> PlanRule:
    d = dict(d)
    match = d.pop("match")
    method = d.pop("method")
    layers = d.pop("layers", None)
    options = dict(d.pop("options", {}))
    options.update(d)                      # loose keys are options
    return PlanRule(match, method, layers, options)


def plan_for_method(method: str, scfg: SLaBConfig = SLaBConfig()
                    ) -> CompressionPlan:
    """The ``method=`` sugar: one catch-all rule."""
    return CompressionPlan([PlanRule("*", method)], base=scfg)


# ------------------------------------------------------------------
# Streaming calibration
# ------------------------------------------------------------------

@dataclasses.dataclass
class CalibrationSpec:
    """Calibration data + streaming policy.

    ``tokens`` is (N, S) int32 ids (or (N, S, D) embeds for
    stub-frontend families). ``batch_size`` sequences are forwarded per
    chunk; tap statistics accumulate across chunks inside one
    ``TapCapture``, so N can exceed what a single forward fits. None
    keeps the single-batch behavior.
    """

    tokens: Any
    batch_size: Optional[int] = None

    def batches(self) -> List[np.ndarray]:
        t = np.asarray(self.tokens)
        bs = self.batch_size or t.shape[0]
        if bs <= 0:
            raise ValueError(f"batch_size must be positive, got {bs}")
        return [t[i:i + bs] for i in range(0, t.shape[0], bs)]
