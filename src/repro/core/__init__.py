"""SLaB core: decomposition algorithm, baselines, packing, forward ops."""
from repro.core.slab import (  # noqa: F401
    SLaBConfig,
    SLaBDecomposition,
    compression_ratio,
    compressed_bits,
    decomposition_error,
    keep_fraction,
    reconstruct,
    slab_decompose,
)
from repro.core.apply import slab_linear, slab_linear_packed, to_dense  # noqa: F401
