"""SLaB core: decomposition algorithm, baselines, packing, forward ops."""
from repro.core.slab import (  # noqa: F401
    SLaBConfig,
    SLaBDecomposition,
    compression_ratio,
    compressed_bits,
    decomposition_error,
    keep_fraction,
    reconstruct,
    slab_decompose,
)
from repro.core.apply import slab_linear, slab_linear_packed, to_dense  # noqa: F401
from repro.core.compressor import (  # noqa: F401
    CompressedLinear,
    Compressor,
    LinearStats,
)
from repro.core.plan import (  # noqa: F401
    CalibrationSpec,
    CompressionPlan,
    PlanRule,
    plan_for_method,
)
