"""SLaB: Sparse-Lowrank-Binary decomposition (paper Algorithm 1).

    W  ≈  W_S + W_L ⊙ W_B,    W_L = U Vᵀ (rank-1, ≥ 0),  W_B ∈ {±1}

Alternating optimization, each iteration:
    W_B ← sign(W − W_S)                      (§II-A3, optimal given W_L ≥ 0)
    U,V ← rank-1 truncated SVD of |W − W_S|  (§II-A4/A5, Eq. 6)
    S   ← |W − UVᵀ ⊙ W_B| ⊙ ‖X‖₂             (§II-A2, Wanda-style score)
    W_S ← mask_topk(S) ⊙ (W − UVᵀ ⊙ W_B)

Note on Algorithm 1 line 8: the pseudocode writes
``HardThreshold(S, sparsity) ⊘ S_X`` which literally recovers the masked
*magnitudes* |Y_S|; §II-A2 says pruning is performed *based on* the score
("pruning is performed based on the magnitude of scoring matrix S"), i.e.
the score selects positions and the retained *values* are those of
Y_S = W − W_L ⊙ W_B. We implement the latter (mask ⊙ Y_S), which is the
standard Wanda semantics the paper builds on and is what makes the
reconstruction error decrease monotonically in practice.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lowrank, scores, sparsity

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SLaBConfig:
    """Hyper-parameters of the decomposition (paper §II-B)."""

    cr: float = 0.5                 # compression ratio (Eq. 9)
    bits: int = 16                  # bit-width b of W_S values and U/V
    iters: int = 20                 # alternating-optimization steps s
    group: Tuple[int, int] = (1, 0)  # comparison group (1, D_in) by default
    pattern: Optional[str] = None   # "2:4" | "4:8" | None (unstructured)
    rank: int = 1                   # paper default: 1
    # Ablation switches (Table III):
    include_binary: bool = True     # False -> W_S + W_L (signed low rank)
    include_lowrank: bool = True    # False with include_binary -> W_S only
    factor_mode: bool = False       # True -> W_S + factor-vector ⊙ W_B
    svd_iters: int = 48


class SLaBDecomposition(NamedTuple):
    """Compressed form of one linear layer's weight.

    w_s  : (D_out, D_in) dense-masked sparse component (storage formats in
           core.packing / kernels expect exactly this + its mask).
    u, v : (D_out, r), (D_in, r) low-rank factors, W_L = u @ v.T.
    w_b  : (D_out, D_in) int8 in {+1, -1}.
    """

    w_s: Array
    u: Array
    v: Array
    w_b: Array


def keep_fraction(
    cr: float,
    bits: int,
    d_out: int,
    d_in: int,
    *,
    rank: int = 1,
    include_binary: bool = True,
    include_lowrank: bool = True,
) -> float:
    """Paper Eq. (10): non-zero fraction of W_S given the CR budget.

    k/(Do·Di) = 1 − CR − 1/b − r(1/Do + 1/Di); the 1/b term pays for the
    1-bit binary matrix and the r(…) terms for the rank-r factor vectors.
    Ablation variants drop the terms for components they do not store.
    """
    f = 1.0 - cr
    if include_binary:
        f -= 1.0 / bits
    if include_lowrank:
        f -= rank * (1.0 / d_out + 1.0 / d_in)
    if f <= 0:
        raise ValueError(
            f"CR={cr} infeasible for shape ({d_out},{d_in}) at b={bits}"
        )
    return f


def compressed_bits(dec: SLaBDecomposition, bits: int = 16) -> int:
    """Exact storage cost in bits (Eq. 9 numerator)."""
    nnz = int(jnp.sum(dec.w_s != 0))
    total = nnz * bits
    if dec.w_b is not None and dec.w_b.size:
        total += dec.w_b.shape[0] * dec.w_b.shape[1]  # 1 bit each
    if dec.u is not None and dec.u.size:
        r = dec.u.shape[1] if dec.u.ndim > 1 else 1
        total += bits * r * (dec.u.shape[0] + dec.v.shape[0])
    return total


def compression_ratio(dec: SLaBDecomposition, bits: int = 16) -> float:
    d_out, d_in = dec.w_s.shape
    return 1.0 - compressed_bits(dec, bits) / (bits * d_out * d_in)


def low_rank_times_binary(dec: SLaBDecomposition) -> Array:
    """W_L ⊙ W_B (handles the ablation cases with missing components)."""
    d_out, d_in = dec.w_s.shape
    if dec.u is None or not dec.u.size:
        lr = jnp.zeros((d_out, d_in), jnp.float32)
    else:
        lr = lowrank.low_rank_matrix(dec.u, dec.v)
    if dec.w_b is None or not dec.w_b.size:
        return lr
    return lr * dec.w_b.astype(jnp.float32)


def reconstruct(dec: SLaBDecomposition) -> Array:
    """Ŵ = W_S + W_L ⊙ W_B."""
    return dec.w_s.astype(jnp.float32) + low_rank_times_binary(dec)


def _fit_residual(y_bl: Array, cfg: SLaBConfig) -> Tuple[Array, Array, Array]:
    """Fit (u, v, w_b) to the residual Y_BL = W − W_S under cfg's ablation
    flags. Returns (u, v, w_b) with empty arrays for absent components."""
    d_out, d_in = y_bl.shape
    f32 = y_bl.astype(jnp.float32)
    empty_u = jnp.zeros((d_out, 0), jnp.float32)
    empty_v = jnp.zeros((d_in, 0), jnp.float32)
    empty_b = jnp.zeros((0, 0), jnp.int8)

    if not cfg.include_lowrank and not cfg.include_binary:
        return empty_u, empty_v, empty_b

    if cfg.include_binary:
        # W_B = sign(Y_BL), sign(0) := +1  (paper Eq. 6)
        w_b = jnp.where(f32 >= 0, 1, -1).astype(jnp.int8)
        if not cfg.include_lowrank:
            return empty_u, empty_v, w_b
        y_abs = jnp.abs(f32)
        if cfg.factor_mode:
            # Table III "factor ⊙ W_B": per-row scale (quantization-factor
            # vector), i.e. rank-1 with v fixed to ones.
            u = jnp.mean(y_abs, axis=1, keepdims=True)
            v = jnp.ones((d_in, 1), jnp.float32)
            return u, v, w_b
        if cfg.rank == 1:
            u, v = lowrank.slab_rank1_factors(y_abs, iters=cfg.svd_iters)
            return u[:, None], v[:, None], w_b
        s, u, v = lowrank.truncated_svd(y_abs, cfg.rank, iters=cfg.svd_iters)
        root = jnp.sqrt(jnp.maximum(s, 0.0))
        return u * root[None, :], v * root[None, :], w_b
    # Low-rank only (Fig. 1 / Table III "W_S + W_L"): signed SVD, no binary.
    s, u, v = lowrank.truncated_svd(f32, cfg.rank, iters=cfg.svd_iters)
    root = jnp.sqrt(jnp.maximum(s, 0.0))
    return u * root[None, :], v * root[None, :], empty_b


def slab_decompose(
    w: Array,
    act_norms: Optional[Array],
    cfg: SLaBConfig = SLaBConfig(),
) -> SLaBDecomposition:
    """Run Algorithm 1 on one weight matrix.

    ``act_norms`` is ``diag(sqrt(X^T X))`` from calibration; ``None`` falls
    back to all-ones (pure magnitude scoring).
    """
    d_out, d_in = w.shape
    w32 = w.astype(jnp.float32)
    if act_norms is None:
        act_norms = jnp.ones((d_in,), jnp.float32)
    act_norms = act_norms.astype(jnp.float32)

    frac = keep_fraction(
        cfg.cr, cfg.bits, d_out, d_in,
        rank=cfg.rank,
        include_binary=cfg.include_binary,
        include_lowrank=cfg.include_lowrank,
    )

    w_s = jnp.zeros_like(w32)
    u = v = None
    w_b = None
    for _ in range(max(cfg.iters, 1)):
        u, v, w_b = _fit_residual(w32 - w_s, cfg)
        lb = low_rank_times_binary(SLaBDecomposition(w_s, u, v, w_b))
        y_s = w32 - lb
        s = jnp.abs(y_s) * act_norms[None, :]
        mask = sparsity.prune_mask(s, frac, group=cfg.group, pattern=cfg.pattern)
        w_s = jnp.where(mask, y_s, 0.0)
    return SLaBDecomposition(w_s.astype(w.dtype), u, v, w_b)


def decomposition_error(
    w: Array,
    dec: SLaBDecomposition,
    act_norms: Optional[Array] = None,
) -> Array:
    return scores.weighted_fro_error(w.astype(jnp.float32), reconstruct(dec), act_norms)
