"""Low-rank factorization primitives.

SLaB only needs the rank-1 truncated SVD of the *non-negative* matrix
|W - W_S| (Algorithm 1, line 6). By Perron-Frobenius the dominant singular
pair of a non-negative matrix can be chosen entry-wise non-negative
(paper Prop. 2), so power iteration started from a positive vector
converges to it without sign ambiguity and without a cuSOLVER-style full
SVD — the TPU/CPU-friendly choice.

Rank-r (r > 1) is used only by the paper's ablations (Table III, Fig. 3);
we provide subspace iteration for moderate r and exact lapack SVD for
small matrices.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def power_rank1(y: Array, iters: int = 64) -> Tuple[Array, Array, Array]:
    """Dominant singular triple (sigma, u, v) of ``y`` via power iteration.

    Deterministic: starts from the normalized row-sum vector, which has a
    non-zero component on the dominant pair for non-negative ``y``.
    """
    y = y.astype(jnp.float32)
    v = jnp.sum(jnp.abs(y), axis=0)
    v = v / jnp.maximum(jnp.linalg.norm(v), 1e-30)

    def body(_, v):
        u = y @ v
        u = u / jnp.maximum(jnp.linalg.norm(u), 1e-30)
        v = y.T @ u
        return v / jnp.maximum(jnp.linalg.norm(v), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    u = y @ v
    sigma = jnp.linalg.norm(u)
    u = u / jnp.maximum(sigma, 1e-30)
    return sigma, u, v


def subspace_svd(y: Array, r: int, iters: int = 24) -> Tuple[Array, Array, Array]:
    """Top-r singular triples via randomized-free subspace (orthogonal)
    iteration. Returns (sigmas (r,), U (Do, r), V (Di, r))."""
    y = y.astype(jnp.float32)
    d_out, d_in = y.shape
    r = min(r, d_out, d_in)
    # Deterministic start: first r columns of a DCT-like basis on row sums.
    k = jnp.arange(d_in, dtype=jnp.float32)[:, None]
    j = jnp.arange(r, dtype=jnp.float32)[None, :]
    v0 = jnp.cos(jnp.pi * (k + 0.5) * j / d_in) * (1.0 + jnp.sum(jnp.abs(y), axis=0))[:, None]
    q, _ = jnp.linalg.qr(v0)

    def body(_, q):
        z = y @ q
        qz, _ = jnp.linalg.qr(z)
        w = y.T @ qz
        q2, _ = jnp.linalg.qr(w)
        return q2

    q = jax.lax.fori_loop(0, iters, body, q)
    b = y @ q  # (Do, r)
    # Small r x r SVD of the projected problem.
    ub, s, vtb = jnp.linalg.svd(b, full_matrices=False)
    u = ub[:, :r]
    v = q @ vtb.T[:, :r]
    return s[:r], u, v


def truncated_svd(y: Array, r: int, iters: int = 32) -> Tuple[Array, Array, Array]:
    """Top-r SVD; exact lapack for small problems, iterative otherwise."""
    if r == 1:
        s, u, v = power_rank1(y, iters=max(iters, 48))
        return s[None], u[:, None], v[:, None]
    d_out, d_in = y.shape
    if max(d_out, d_in) <= 1024:
        u, s, vt = jnp.linalg.svd(y.astype(jnp.float32), full_matrices=False)
        return s[:r], u[:, :r], vt[:r].T
    return subspace_svd(y, r, iters=iters)


def slab_rank1_factors(y_abs: Array, iters: int = 64) -> Tuple[Array, Array]:
    """Paper Eq. (6): U = sqrt(sigma0) u0, V = sqrt(sigma0) v0 of |Y_BL|.

    For non-negative input the returned factors are entry-wise >= 0
    (Prop. 2); we clip tiny negative numerical noise to keep the invariant
    exact.
    """
    sigma, u, v = power_rank1(y_abs, iters=iters)
    root = jnp.sqrt(jnp.maximum(sigma, 0.0))
    return jnp.maximum(u, 0.0) * root, jnp.maximum(v, 0.0) * root


def low_rank_matrix(u: Array, v: Array) -> Array:
    """W_L = U V^T for (Do, r), (Di, r) factors (r may be 1)."""
    if u.ndim == 1:
        u = u[:, None]
    if v.ndim == 1:
        v = v[:, None]
    return u @ v.T
