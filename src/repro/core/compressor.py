"""Pluggable per-linear weight compressors: protocol + registry.

A ``Compressor`` turns one weight matrix (paper convention: (D_out,
D_in)) plus its tapped calibration statistics into a ``CompressedLinear``
— a dense equivalent for XLA serving, an optional structured
decomposition for the packed Pallas path, and the *measured* compression
ratio. Compressors declare which statistics they need via ``needs``
(subset of {"norms", "hessian"}); the pipeline taps exactly those, so a
plan that routes every linear to Wanda never pays for O(T·D²) Gram
accumulation.

Registering a new method needs zero edits to ``core.pipeline``::

    from repro.core import compressor

    @compressor.register("mymethod")
    class MyCompressor(compressor.Compressor):
        needs = frozenset({"norms"})

        def compress(self, w, stats):
            out = ...                       # (D_out, D_in) fp32
            return compressor.CompressedLinear(out, None, measured_cr)

then select it from any plan rule: ``"mlp.*=mymethod@cr=0.6"``.

Built-ins: ``slab`` (Algorithm 1), the paper's baselines ``wanda`` /
``magnitude`` / ``sparsegpt``, ``hassle`` — a HASSLE-free-style
alternating sparse + low-rank decomposition (Makni et al. 2025) driven
by the per-linear X^T X the taps already collect — and ``sola``, a
SoLA-style soft activation-aware pruner (score-space soft-threshold
instead of hard masking). Every built-in returns a decomposition the
packed-serving path can classify (core.packed_model.variant_of), so
mixed plans serve fully on the fused kernels.
"""
from __future__ import annotations

from typing import Dict, FrozenSet, NamedTuple, Optional, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines as base_lib
from repro.core.slab import (SLaBConfig, SLaBDecomposition,
                             compression_ratio, keep_fraction, reconstruct,
                             slab_decompose)

Array = jax.Array


class LinearStats(NamedTuple):
    """Per-linear calibration statistics from the activation taps.

    norms   : (D_in,) ‖X_j‖₂ column norms, or None if not collected.
    hessian : (D_in, D_in) Gram matrix X^T X, or None unless the
              compressor's ``needs`` requested it.
    """

    norms: Optional[Array] = None
    hessian: Optional[Array] = None


class CompressedLinear(NamedTuple):
    """Result of compressing one (D_out, D_in) weight matrix.

    dense : (D_out, D_in) fp32 dense equivalent (what XLA serves).
    dec   : structured decomposition for the packed kernel path —
            pruning-only methods return a sparse-only dec (empty
            binary/low-rank terms) so their layers still pack; None
            means the linear can only serve dense.
    cr    : measured compression ratio (Eq. 9 for decompositions, zero
            fraction for pure pruning); None if not computable.
    """

    dense: Array
    dec: Optional[SLaBDecomposition] = None
    cr: Optional[float] = None


class Compressor:
    """Protocol for per-linear compression methods.

    Subclasses set ``needs`` (which tap statistics to collect) and
    implement ``compress(w, stats)``. ``w`` arrives as (D_out, D_in)
    fp32; per-rule hyper-parameters come in as a ``SLaBConfig`` (the
    shared bundle: cr / pattern / group / iters / rank / bits), extra
    keyword options are forwarded to ``__init__``.
    """

    name: str = ""
    needs: FrozenSet[str] = frozenset()

    def __init__(self, scfg: SLaBConfig = SLaBConfig()):
        self.scfg = scfg

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        raise NotImplementedError

    def keep_fraction_for(self, cr: float, d_out: int, d_in: int) -> float:
        """Fraction of W_S entries this method keeps at compression
        ratio ``cr`` on a (d_out, d_in) matrix — the budget-allocator
        probe hook (``core.allocator``). The base model is pure pruning
        (survivors keep their full bit-width); methods that spend budget
        on other terms (binary / low-rank factors) override. Return
        <= 0 when ``cr`` is infeasible for the shape."""
        return 1.0 - cr


# ------------------------------------------------------------------
# Registry
# ------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Compressor]] = {}


def register(name: str):
    """Class decorator: ``@register("mymethod")``."""

    def deco(cls: Type[Compressor]) -> Type[Compressor]:
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def get(name: str, scfg: SLaBConfig = SLaBConfig(), **kw) -> Compressor:
    """Instantiate a registered compressor by name."""
    if name not in _REGISTRY:
        raise KeyError(f"unknown compressor {name!r}; "
                       f"available: {available()}")
    return _REGISTRY[name](scfg, **kw)


def available() -> list:
    return sorted(_REGISTRY)


# ------------------------------------------------------------------
# Built-ins
# ------------------------------------------------------------------

def _pruned_cr(dense: Array) -> float:
    """Measured CR of a pruning-only result: the zero fraction (pruned
    values cost nothing, survivors keep their full bit-width)."""
    return float(jnp.mean(dense == 0))


def _sparse_only_dec(w_s: Array) -> SLaBDecomposition:
    """Sparse-only decomposition (no binary / low-rank terms): what a
    pruning method hands the packed-serving path so its layers ride the
    N:M kernel (or the dense-masked format tag) instead of falling back
    to dense XLA."""
    d_out, d_in = w_s.shape
    return SLaBDecomposition(
        w_s=w_s,
        u=jnp.zeros((d_out, 0), jnp.float32),
        v=jnp.zeros((d_in, 0), jnp.float32),
        w_b=jnp.zeros((0, 0), jnp.int8))


@register("slab")
class SLaBCompressor(Compressor):
    """Paper Algorithm 1: W ≈ W_S + W_L ⊙ W_B (incl. ablation modes)."""

    needs = frozenset({"norms"})

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        dec = slab_decompose(w, stats.norms, self.scfg)
        return CompressedLinear(reconstruct(dec), dec,
                                compression_ratio(dec, self.scfg.bits))

    def keep_fraction_for(self, cr: float, d_out: int, d_in: int) -> float:
        try:
            return keep_fraction(cr, self.scfg.bits, d_out, d_in,
                                 rank=self.scfg.rank,
                                 include_binary=self.scfg.include_binary,
                                 include_lowrank=self.scfg.include_lowrank)
        except ValueError:
            return 0.0


@register("wanda")
class WandaCompressor(Compressor):
    """|W| · ‖X‖₂ scoring, no weight update (Sun et al. 2023)."""

    needs = frozenset({"norms"})

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        an = (stats.norms if stats.norms is not None
              else jnp.ones((w.shape[1],), jnp.float32))
        out = base_lib.wanda_prune(w, an, 1.0 - self.scfg.cr,
                                   group=self.scfg.group,
                                   pattern=self.scfg.pattern)
        return CompressedLinear(out, _sparse_only_dec(out), _pruned_cr(out))


@register("magnitude")
class MagnitudeCompressor(Compressor):
    """|W| scoring; needs no calibration statistics at all."""

    needs = frozenset()

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        out = base_lib.magnitude_prune(w, 1.0 - self.scfg.cr,
                                       group=self.scfg.group,
                                       pattern=self.scfg.pattern)
        return CompressedLinear(out, _sparse_only_dec(out), _pruned_cr(out))


@register("sparsegpt")
class SparseGPTCompressor(Compressor):
    """Hessian-aware OBS pruning with error propagation."""

    needs = frozenset({"hessian"})

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        if stats.hessian is None:
            raise ValueError("sparsegpt needs the tapped X^T X Hessian")
        out = base_lib.sparsegpt_prune(w, stats.hessian,
                                       1.0 - self.scfg.cr,
                                       pattern=self.scfg.pattern)
        return CompressedLinear(out, _sparse_only_dec(out), _pruned_cr(out))


@register("hassle")
class HassleFreeCompressor(Compressor):
    """HASSLE-free-style alternating sparse + low-rank decomposition:
    W ≈ W_S + U Vᵀ, no binary component (Makni et al. 2025).

    Both subproblems are solved in the calibration metric H = X^T X
    (tr(E H Eᵀ) = ‖E L_c‖_F² for H = L_c L_cᵀ):

      L-step: rank-r truncated SVD of (W − W_S) L_c, mapped back
              through L_c⁻¹ — the optimal low-rank update under the
              Hessian-weighted Frobenius norm;
      S-step: SparseGPT OBS pruning of the residual W − U Vᵀ under the
              same Hessian, at the Eq.-10 keep fraction that charges
              the rank-r factors against the CR budget.

    ``rank`` comes from ``scfg.rank``; ``alt_iters`` controls the
    alternation count (each round pays one SVD + one OBS sweep).
    """

    needs = frozenset({"norms", "hessian"})

    def __init__(self, scfg: SLaBConfig = SLaBConfig(),
                 alt_iters: int = 3, percdamp: float = 0.01):
        super().__init__(scfg)
        self.alt_iters = int(alt_iters)
        self.percdamp = float(percdamp)

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        if stats.hessian is None:
            raise ValueError("hassle needs the tapped X^T X Hessian")
        d_out, d_in = w.shape
        r = max(self.scfg.rank, 1)
        frac = keep_fraction(self.scfg.cr, self.scfg.bits, d_out, d_in,
                             rank=r, include_binary=False,
                             include_lowrank=True)

        h = np.array(stats.hessian, dtype=np.float64).copy()
        dead = np.diag(h) == 0
        h[dead, dead] = 1.0
        h[np.arange(d_in), np.arange(d_in)] += (
            self.percdamp * float(np.mean(np.diag(h))))
        lc = np.linalg.cholesky(h)                       # H = L_c L_cᵀ

        w64 = np.array(w, dtype=np.float64)
        w64[:, dead] = 0.0
        w_s = np.zeros_like(w64)
        low = np.zeros_like(w64)
        u_f = np.zeros((d_out, r))
        v_f = np.zeros((d_in, r))
        for _ in range(max(self.alt_iters, 1)):
            m = (w64 - w_s) @ lc
            um, sv, vtm = np.linalg.svd(m, full_matrices=False)
            um, sv, vtm = um[:, :r], sv[:r], vtm[:r]
            mr = (um * sv[None, :]) @ vtm                # (D_out, D_in)
            low = np.linalg.solve(lc.T, mr.T).T          # M_r L_c⁻¹
            root = np.sqrt(np.maximum(sv, 0.0))
            u_f = um * root[None, :]
            v_f = np.linalg.solve(lc.T, vtm.T) * root[None, :]
            w_s = np.asarray(
                base_lib.sparsegpt_prune(
                    jnp.asarray(w64 - low, jnp.float32),
                    jnp.asarray(h, jnp.float32), frac,
                    pattern=self.scfg.pattern,
                    percdamp=self.percdamp),
                dtype=np.float64)

        dec = SLaBDecomposition(
            w_s=jnp.asarray(w_s, jnp.float32),
            u=jnp.asarray(u_f, jnp.float32),
            v=jnp.asarray(v_f, jnp.float32),
            w_b=jnp.zeros((0, 0), jnp.int8))             # no binary term
        dense = jnp.asarray(w_s + low, jnp.float32)
        return CompressedLinear(dense, dec,
                                compression_ratio(dec, self.scfg.bits))

    def keep_fraction_for(self, cr: float, d_out: int, d_in: int) -> float:
        try:
            return keep_fraction(cr, self.scfg.bits, d_out, d_in,
                                 rank=max(self.scfg.rank, 1),
                                 include_binary=False,
                                 include_lowrank=True)
        except ValueError:
            return 0.0


@register("sola")
class SoLACompressor(Compressor):
    """SoLA-style soft activation-aware sparsity from the tapped norms.

    The Wanda score s = |W| · ‖X‖₂ picks the kept positions; instead of
    copying survivors verbatim (hard masking), they pass through the
    score-space soft-threshold — the proximal operator of the
    activation-weighted L1 penalty λ‖diag(‖X‖₂) ∘ W‖₁:

        w_ij ← sign(w_ij) · (|w_ij| − softness · λ / ‖X_j‖₂)₊

    with λ the smallest *kept* score, so the kept/zeroed transition is
    continuous in the score instead of a cliff. ``softness=0`` reduces
    exactly to ``wanda``; ``softness`` must stay < 1 because the full
    prox step would zero the boundary survivor whose score equals λ
    exactly — with strict shrinkage every kept score ≥ λ (group top-k
    keeps the best of each comparison group) leaves a non-zero residual,
    so the support equals Wanda's, the measured CR equals the requested
    zero fraction, and the result packs as a sparse-only variant like
    the other pruners.
    """

    needs = frozenset({"norms"})

    def __init__(self, scfg: SLaBConfig = SLaBConfig(),
                 softness: float = 0.5):
        super().__init__(scfg)
        if not 0.0 <= softness < 1.0:
            raise ValueError(f"softness must be in [0, 1), got {softness}")
        self.softness = float(softness)

    def compress(self, w: Array, stats: LinearStats) -> CompressedLinear:
        from repro.core import sparsity as sparsity_lib
        d_in = w.shape[1]
        an = (stats.norms if stats.norms is not None
              else jnp.ones((d_in,), jnp.float32)).astype(jnp.float32)
        an = jnp.maximum(an, 1e-12)
        s = jnp.abs(w.astype(jnp.float32)) * an[None, :]
        mask = sparsity_lib.prune_mask(s, 1.0 - self.scfg.cr,
                                       group=self.scfg.group,
                                       pattern=self.scfg.pattern)
        lam = jnp.min(jnp.where(mask, s, jnp.inf))   # smallest kept score
        shrink = self.softness * lam / an[None, :]
        out = jnp.where(
            mask,
            jnp.sign(w) * jnp.maximum(jnp.abs(w.astype(jnp.float32))
                                      - shrink, 0.0),
            0.0)
        return CompressedLinear(out, _sparse_only_dec(out), _pruned_cr(out))
