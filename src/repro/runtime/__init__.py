from repro.runtime.sharding import (  # noqa: F401
    Planner, axis_constraints, logical_rules)
