"""Fault tolerance: step watchdog + supervised train loop with
checkpoint/restart and deterministic data replay.

On a real cluster the failure signal is a dead host / collective timeout;
in this container failures are injected (tests) through ``failure_hook``.
The recovery semantics are the production ones:

  - every ``ckpt_every`` steps the manager commits (params, opt, step)
    atomically (temp dir + rename) via an async writer;
  - a step exceeding ``deadline_s`` increments a straggler counter
    (mitigation: at scale this triggers requeue of the slow host; here it
    is recorded and surfaces in metrics);
  - on failure the supervisor restores the last commit and *replays*:
    the synthetic pipeline is keyed by (seed, step, host) so the retrain
    path sees byte-identical batches — recovery is bitwise reproducible.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass
class FaultConfig:
    ckpt_every: int = 50
    deadline_s: float = 300.0
    max_restarts: int = 3
    straggler_factor: float = 3.0   # step > factor×median => straggler


@dataclass
class FaultStats:
    restarts: int = 0
    stragglers: int = 0
    step_times: List[float] = field(default_factory=list)


class Supervisor:
    """Runs (step -> state) callables under checkpoint/restart semantics."""

    def __init__(self, mgr: CheckpointManager, fcfg: FaultConfig = FaultConfig(),
                 failure_hook: Optional[Callable[[int], bool]] = None):
        self.mgr = mgr
        self.fcfg = fcfg
        self.failure_hook = failure_hook or (lambda step: False)
        self.stats = FaultStats()

    def run(self,
            state: Any,
            start_step: int,
            n_steps: int,
            step_fn: Callable[[Any, int], Any],
            restore_fn: Callable[[int], Any],
            on_metrics: Optional[Callable[[int, Dict], None]] = None) -> Any:
        """step_fn(state, step) -> (state, metrics). restore_fn(step) ->
        state restored from the last commit at-or-before ``step``."""
        step = start_step
        while step < n_steps:
            t0 = time.monotonic()
            try:
                if self.failure_hook(step):
                    raise RuntimeError(f"injected failure at step {step}")
                state, metrics = step_fn(state, step)
            except Exception:
                if self.stats.restarts >= self.fcfg.max_restarts:
                    raise
                self.stats.restarts += 1
                self.mgr.wait()
                last = self.mgr.latest_step()
                if last is None:
                    raise
                state = restore_fn(last)
                step = last
                continue
            dt = time.monotonic() - t0
            self.stats.step_times.append(dt)
            med = float(np.median(self.stats.step_times))
            if (len(self.stats.step_times) > 5 and
                    dt > self.fcfg.straggler_factor * med):
                self.stats.stragglers += 1
            if dt > self.fcfg.deadline_s:
                self.stats.stragglers += 1
            if on_metrics:
                on_metrics(step, metrics)
            step += 1
            if step % self.fcfg.ckpt_every == 0:
                self.mgr.save(step, state)
        self.mgr.wait()
        return state
