"""Ambient mesh context so model code can drop sharding *hints* without
carrying a mesh argument through every layer.

Step builders / the dry-run enter ``with use_mesh(mesh):``; model code
calls ``hint(x, names...)`` which becomes a with_sharding_constraint when
a mesh is active (and every named dim divides), and a no-op otherwise —
smoke tests on one CPU device never see a constraint.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def hint(x: jax.Array, *names) -> jax.Array:
    """Constrain dim i of x to mesh axis names[i]; None leaves the dim
    UNCONSTRAINED (GSPMD keeps whatever propagates). A name may be a
    tuple of axis names (e.g. ("pod", "data") for the multi-pod batch
    dim) — axes missing from the mesh are dropped from the tuple, and
    the whole entry falls back to UNCONSTRAINED if the surviving axes do
    not divide the dim."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    parts = []
    any_named = False
    for name, dim in zip(names, x.shape):
        if name is None:
            parts.append(P.UNCONSTRAINED)
            continue
        axes = name if isinstance(name, tuple) else (name,)
        axes = tuple(a for a in axes if a in mesh.axis_names)
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if axes and dim % n == 0:
            parts.append(axes if len(axes) > 1 else axes[0])
            any_named = True
        else:
            parts.append(P.UNCONSTRAINED)
    if not any_named:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*parts)))


DP = ("pod", "data")   # the batch/DP axes convention of this framework
