"""Elastic scaling: restore a checkpoint onto a *different* mesh.

The checkpoint format is mesh-agnostic (host numpy per leaf); the new
job builds its own Planner for whatever mesh it was given and re-places
every leaf with ``jax.device_put(arr, new_sharding)``. Growing 256 -> 512
chips, shrinking, or changing the (data, model) split are all the same
code path. Used by tests (save on mesh A, restore on mesh B, bitwise
equality) and by launch/train.py --restore.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.sharding import Planner


def train_state_template(cfg: ArchConfig, acfg: AdamWConfig):
    """Abstract {"params", "opt"} tree (the launcher's commit unit)."""
    shapes, axes = lm.abstract_params(cfg)
    opt_shapes = jax.eval_shape(lambda: adamw_init(shapes, acfg))
    return {"params": shapes, "opt": opt_shapes}


def train_state_shardings(cfg: ArchConfig, acfg: AdamWConfig, mesh: Mesh):
    planner = Planner(mesh, cfg)
    shapes, axes = lm.abstract_params(cfg)
    p_sh = planner.tree_shardings(axes, shapes)
    opt_shapes = jax.eval_shape(lambda: adamw_init(shapes, acfg))
    opt_axes = type(opt_shapes)(axes, axes, ())
    o_sh = planner.tree_shardings(opt_axes, opt_shapes)
    return {"params": p_sh, "opt": o_sh}


def elastic_restore(mgr: CheckpointManager, cfg: ArchConfig,
                    acfg: AdamWConfig, mesh: Mesh,
                    step: Optional[int] = None):
    """Restore the {"params", "opt"} commit onto ``mesh`` (any shape),
    resharding every leaf for the new topology."""
    template = train_state_template(cfg, acfg)
    shardings = train_state_shardings(cfg, acfg, mesh)
    return mgr.restore(template, step=step, shardings=shardings)
