"""Train / serve step builders — the pjit programs the launcher and the
dry-run lower.

train_step(params, opt_state, batch) -> (params', opt_state', metrics)
  - microbatched gradient accumulation (lax.scan over microbatch slices;
    live activation memory = one microbatch) — mandatory at 340B scale.
  - configurable remat policy applied to the layer scan body.
  - AdamW update with sharded optimizer state (inherits param shardings).
  - donate params/opt_state (in-place buffer reuse).

serve_step(params, cache, token, positions) -> (logits, cache')
prefill(params, inputs[, positions]) -> logits

All steps install activation sharding constraints (batch over DP axes)
at the program boundary; interior shardings propagate via GSPMD from the
parameter/cache shardings.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamWConfig, adamw_update
from repro.runtime.sharding import Planner

REMAT_POLICIES = {
    "none": None,                                          # no jax.checkpoint
    "nothing": jax.checkpoint_policies.nothing_saveable,   # recompute all
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    "everything": jax.checkpoint_policies.everything_saveable,
}


def _split_microbatches(batch: Dict[str, Any], n: int) -> Dict[str, Any]:
    return {k: v.reshape(n, v.shape[0] // n, *v.shape[1:])
            for k, v in batch.items()}


def make_train_fn(cfg: ArchConfig, acfg: AdamWConfig, planner: Planner,
                  microbatches: int = 1, remat: str = "nothing",
                  grad_dtype=jnp.float32):
    """The pure function (params, opt_state, batch) -> outputs.

    ``remat`` is one of REMAT_POLICIES or "blocks:<K>" (sqrt-L block
    checkpointing with nothing saveable inside a K-layer block)."""
    remat_block = 1
    if remat.startswith("blocks:"):
        remat_block = int(remat.split(":")[1])
        policy = REMAT_POLICIES["nothing"]
        remat = "blocks"
    else:
        policy = REMAT_POLICIES[remat]
    mesh = planner.mesh
    dp = planner.batch_axes()
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)

    def constrain_batch(mb):
        def c(x):
            spec = P(bspec, *((None,) * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return {k: c(v) for k, v in mb.items()}

    def loss_of(params, mb):
        mb = constrain_batch(mb)
        if remat == "none":
            return lm.loss_fn(cfg, params, mb, None)
        return lm.loss_fn(cfg, params, mb, policy, remat_block)

    grad_fn = jax.value_and_grad(lambda p, mb: loss_of(p, mb), has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mbs = _split_microbatches(batch, microbatches)

            def acc_step(carry, mb):
                gacc, laux = carry
                (loss, aux), g = grad_fn(params, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(grad_dtype), gacc, g)
                return (gacc, laux + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype),
                              params)
            (gsum, lsum), _ = jax.lax.scan(acc_step, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            (loss, aux), grads = grad_fn(params, batch)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               acfg)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def jit_train_step(cfg: ArchConfig, acfg: AdamWConfig, planner: Planner,
                   param_shardings, opt_shardings, batch_shardings,
                   microbatches: int = 1, remat: str = "nothing",
                   donate: bool = True):
    fn = make_train_fn(cfg, acfg, planner, microbatches, remat)
    rep = NamedSharding(planner.mesh, P())
    metric_sh = {"loss": rep, "grad_norm": rep, "lr": rep}
    return jax.jit(
        fn,
        in_shardings=(param_shardings, opt_shardings, batch_shardings),
        out_shardings=(param_shardings, opt_shardings, metric_sh),
        donate_argnums=(0, 1) if donate else (),
    )


def make_serve_fn(cfg: ArchConfig, planner: Planner):
    def serve_step(params, cache, token, positions):
        logits, new_cache = lm.decode_step(cfg, params, cache, token,
                                           positions)
        return logits, new_cache
    return serve_step


def jit_serve_step(cfg: ArchConfig, planner: Planner, param_shardings,
                   cache_shardings, token_sharding, pos_sharding,
                   donate_cache: bool = True):
    fn = make_serve_fn(cfg, planner)
    mesh = planner.mesh
    logits_sh = NamedSharding(
        mesh, P(token_sharding.spec[0] if token_sharding.spec else None,
                None, "model" if cfg.vocab % mesh.shape["model"] == 0
                else None))
    return jax.jit(
        fn,
        in_shardings=(param_shardings, cache_shardings, token_sharding,
                      pos_sharding),
        out_shardings=(logits_sh, cache_shardings),
        donate_argnums=(1,) if donate_cache else (),
    )


def make_prefill_fn(cfg: ArchConfig, planner: Planner):
    def prefill(params, inputs, positions=None):
        return lm.prefill(cfg, params, inputs, positions)
    return prefill
