"""Divisibility-aware sharding planner: logical axes -> PartitionSpecs.

Rules (train and serve share the 2-D layout — weights are FSDP x TP
sharded; serve keeps 2-D because the 340B config cannot replicate over
"data"; the §Perf hillclimb revisits this for small decode cells):

  logical name  candidate mesh axes (first that divides wins)
  ------------  -----------------------------------------------
  vocab         ("model",)
  embed         ("pod","data") -> ("data",)     [FSDP; ZeRO over pod]
  heads/kv      ("model",)  with whole-head alignment (unit=d_head)
  ffn           ("model",)
  experts       ("model",)                      [EP]
  ssm           ("model",)  unit=ssm_headdim
  ssm_heads     ("model",)
  batch         ("pod","data") -> ("data",)     [activations/caches]
  kv_seq        ("model",)                      [SP flash-decode split]
  packed_out    ("model",)                      [packed-linear d_out rows]
  layers        never sharded (scan axis)

A rule applies only if the dim size divides by the product of the mesh
axes AND the per-shard slice keeps logical units intact (e.g. a GQA
llama3.2-3b has 24 q-heads: 24*128/16 leaves 192 ≡ 1.5 heads -> rule is
dropped and attention replicates over "model" while its MLP still TP-
shards — the documented degraded-but-correct fallback).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig, is_axes_leaf

AxisRule = Sequence[Tuple[str, ...]]     # candidates, in priority order


def logical_rules(multi_pod: bool) -> Dict[str, AxisRule]:
    fsdp = [("pod", "data"), ("data",)] if multi_pod else [("data",)]
    return {
        "vocab": [("model",)],
        "embed": fsdp,
        "heads": [("model",)],
        "kv": [("model",)],
        "ffn": [("model",)],
        "experts": [("model",)],
        "ssm": [("model",)],
        "ssm_heads": [("model",)],
        "batch": fsdp,
        "kv_seq": [("model",)],
        # packed-serving formats (core.packed_model): every stored plane
        # of a PackedLinear except v leads with d_out, so TP = row
        # sharding on "model"; divisibility fallback = replicate
        "packed_out": [("model",)],
        "layers": [],
        # paged KV pools (serving.paged_cache): blocks are a global
        # free pool — any request may own any block, so the block dim
        # is never sharded; TP splits the kv-head dim as usual
        "kv_blocks": [],
        "kv_heads": [("model",)],
    }


def axis_constraints(cfg: ArchConfig) -> Dict[str, int]:
    """Units that must stay whole inside one shard."""
    return {
        "heads": cfg.d_head,
        "kv": cfg.d_head,
        "ssm": max(cfg.ssm_headdim, 1),
    }


class Planner:
    def __init__(self, mesh: Mesh, cfg: ArchConfig,
                 rules: Optional[Dict[str, AxisRule]] = None):
        self.mesh = mesh
        self.cfg = cfg
        multi_pod = "pod" in mesh.axis_names
        self.rules = rules if rules is not None else logical_rules(multi_pod)
        self.units = axis_constraints(cfg)

    def _pick(self, name: Optional[str], dim: int) -> Optional[Tuple[str, ...]]:
        if name is None:
            return None
        for cand in self.rules.get(name, []):
            if any(a not in self.mesh.axis_names for a in cand):
                continue
            n_shards = math.prod(self.mesh.shape[a] for a in cand)
            if dim % n_shards:
                continue
            unit = self.units.get(name, 1)
            if (dim // n_shards) % unit:
                continue
            return cand
        return None

    def spec(self, axes: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
        assert len(axes) == len(shape), (axes, shape)
        used: set = set()
        parts = []
        for name, dim in zip(axes, shape):
            cand = self._pick(name, dim)
            if cand is not None and not (set(cand) & used):
                used.update(cand)
                parts.append(cand if len(cand) > 1 else cand[0])
            else:
                parts.append(None)
        return P(*parts)

    def sharding(self, axes, shape) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(axes, shape))

    # ---- pytree versions ------------------------------------------------

    def tree_specs(self, axes_tree: Any, shapes_tree: Any) -> Any:
        return jax.tree.map(
            lambda ax, leaf: self.spec(ax, tuple(leaf.shape)),
            axes_tree, shapes_tree, is_leaf=is_axes_leaf)

    def tree_shardings(self, axes_tree: Any, shapes_tree: Any) -> Any:
        specs = self.tree_specs(axes_tree, shapes_tree)
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), specs,
                            is_leaf=lambda x: isinstance(x, P))

    # ---- activations ----------------------------------------------------

    def act_spec(self, *names: Optional[str], shape: Tuple[int, ...]) -> P:
        return self.spec(tuple(names), shape)

    def batch_axes(self) -> Tuple[str, ...]:
        for cand in self.rules["batch"]:
            if all(a in self.mesh.axis_names for a in cand):
                return cand
        return ()


def constrain(x, mesh: Mesh, spec: P):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
