"""shard_map data-parallel train step with int8 error-feedback gradient
compression on the DP all-reduce.

Two-phase compressed all-reduce (1-bit-Adam lineage, adapted to XLA
collectives):
  1. each replica quantizes (grad + error feedback) per-tensor to int8,
  2. all_to_all exchanges int8 *shards* (each device collects every
     replica's slice of its own shard),
  3. local dequant-sum over replicas, requantize,
  4. all_gather of the reduced int8 shards + scales.

Wire traffic ≈ 2·n int8 bytes vs ≈ 8·n bytes for a ring f32 all-reduce:
a 4× DP-bandwidth saving, which is what crosses the slow "pod" axis in
the multi-pod mesh. Error feedback accumulates the quantization residual
into the next step so the compression is unbiased over time.

This is the explicit-collective variant of the train step (the pjit path
in runtime.step lets XLA choose collectives); it is exercised at small
scale by tests/examples and is the reference implementation of the
distributed-optimization trick for the 1000+-node posture.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamWConfig, adamw_update

Array = jax.Array
AXIS = "data"


def _compressed_allreduce_mean(g: Array, err: Array, n_dev: int):
    """One tensor: returns (mean grad f32, new error buffer)."""
    g32 = g.astype(jnp.float32) + err
    # --- quantize local
    scale = jnp.maximum(jnp.max(jnp.abs(g32)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    new_err = g32 - q.astype(jnp.float32) * scale

    flat = q.reshape(-1)
    pad = (-flat.shape[0]) % n_dev
    flat = jnp.pad(flat, (0, pad))
    shards = flat.reshape(n_dev, -1)                       # (R, n/R) int8

    # --- phase 1: exchange shards + scales
    recv = jax.lax.all_to_all(shards, AXIS, split_axis=0, concat_axis=0,
                              tiled=False)                 # (R, n/R)
    scales = jax.lax.all_gather(scale, AXIS)               # (R,)
    local_sum = jnp.sum(recv.astype(jnp.float32) *
                        scales[:, None], axis=0)           # (n/R,) f32

    # --- phase 2: requantize the reduced shard, all_gather
    s2 = jnp.maximum(jnp.max(jnp.abs(local_sum)) / 127.0, 1e-12)
    q2 = jnp.clip(jnp.round(local_sum / s2), -127, 127).astype(jnp.int8)
    all_q = jax.lax.all_gather(q2, AXIS)                   # (R, n/R) int8
    all_s = jax.lax.all_gather(s2, AXIS)                   # (R,)
    full = (all_q.astype(jnp.float32) * all_s[:, None]).reshape(-1)
    if pad:
        full = full[:-pad]
    return full.reshape(g.shape) / n_dev, new_err


def build_compressed_ddp_step(cfg: ArchConfig, acfg: AdamWConfig,
                              mesh: Mesh, compress: bool = True):
    """(params, opt_state, err_bufs, batch) -> (params', opt', err', metrics).
    Params replicated; batch sharded over "data"."""
    n_dev = mesh.shape[AXIS]

    def local_step(params, opt_state, err, batch):
        (loss, aux), grads = jax.value_and_grad(
            lambda p: lm.loss_fn(cfg, p, batch)[0], has_aux=False)(params), None
        return loss, grads

    def step(params, opt_state, err, batch):
        def loss_fn(p):
            l, _ = lm.loss_fn(cfg, p, batch)
            return l
        loss, grads = jax.value_and_grad(loss_fn)(params)
        loss = jax.lax.pmean(loss, AXIS)
        if compress:
            out = jax.tree.map(
                lambda g, e: _compressed_allreduce_mean(g, e, n_dev),
                grads, err)
            grads = jax.tree.map(lambda t: t[0], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
            err = jax.tree.map(lambda t: t[1], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        else:
            grads = jax.lax.pmean(grads, AXIS)
        new_params, new_opt, om = adamw_update(grads, opt_state, params,
                                               acfg)
        return new_params, new_opt, err, {"loss": loss, **om}

    rep = P()
    shd = P(AXIS)
    batch_spec = {"inputs": shd, "labels": shd}
    return jax.jit(shard_map(
        step, mesh=mesh,
        in_specs=(rep, rep, rep, batch_spec),
        out_specs=(rep, rep, rep, rep),
        check_rep=False,
    ))


def init_error_buffers(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
