"""Abstract input/state specs for every (arch x shape) cell — ShapeDtype
Struct stand-ins with shardings attached; nothing is ever allocated.
This is what both the dry-run and the roofline analysis lower against.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeSpec
from repro.models import lm
from repro.models.common import ArchConfig
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.runtime.sharding import Planner


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def abstract_tree(planner: Planner, shapes_tree: Any, axes_tree: Any) -> Any:
    """ShapeDtypeStruct pytree with NamedShardings from the planner."""
    shardings = planner.tree_shardings(axes_tree, shapes_tree)
    return jax.tree.map(
        lambda leaf, sh: _sds(leaf.shape, leaf.dtype, sh),
        shapes_tree, shardings)


def abstract_params(cfg: ArchConfig, planner: Planner):
    shapes, axes = lm.abstract_params(cfg)
    return abstract_tree(planner, shapes, axes), axes


def abstract_opt_state(cfg: ArchConfig, planner: Planner,
                       acfg: AdamWConfig):
    shapes, axes = lm.abstract_params(cfg)
    opt_shapes = jax.eval_shape(lambda: adamw_init(shapes, acfg))
    opt_axes = type(opt_shapes)(axes, axes, ())
    return abstract_tree(planner, opt_shapes, opt_axes), opt_axes


def batch_specs(cfg: ArchConfig, shape: ShapeSpec, planner: Planner
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Training batch: inputs/labels (+ M-RoPE positions for vlm)."""
    b, s = shape.global_batch, shape.seq_len
    mesh = planner.mesh
    dp = planner.batch_axes()
    n_dp = 1
    for a in (dp or ()):
        n_dp *= mesh.shape[a]
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    if n_dp == 0 or b % n_dp:
        bspec = None
    tok_sh = NamedSharding(mesh, P(bspec, None))
    out = {}
    if cfg.input_mode == "embeds":
        emb_sh = NamedSharding(mesh, P(bspec, None, None))
        out["inputs"] = _sds((b, s, cfg.d_model), cfg.dtype, emb_sh)
    else:
        out["inputs"] = _sds((b, s), jnp.int32, tok_sh)
    out["labels"] = _sds((b, s), jnp.int32, tok_sh)
    if cfg.rope == "mrope":
        pos_sh = NamedSharding(mesh, P(bspec, None, None))
        out["positions"] = _sds((b, s, 3), jnp.int32, pos_sh)
    return out


def decode_specs(cfg: ArchConfig, shape: ShapeSpec, planner: Planner
                 ) -> Tuple[Any, Any, Any]:
    """(cache, token, positions) specs for a serve_step at cache length
    shape.seq_len with batch shape.global_batch."""
    b, s = shape.global_batch, shape.seq_len
    mesh = planner.mesh
    cache_shapes = jax.eval_shape(lambda: lm.init_cache(cfg, b, s, length=s))
    cache_axes = lm.cache_axes(cfg)
    cache = abstract_tree(planner, cache_shapes, cache_axes)
    dp = planner.batch_axes()
    bspec = dp if len(dp) > 1 else (dp[0] if dp else None)
    n_dp = 1
    for a in (dp or ()):
        n_dp *= mesh.shape[a]
    if b % n_dp:
        bspec = None
    tok_sh = NamedSharding(mesh, P(bspec, None))
    token = _sds((b, 1), jnp.int32, tok_sh)
    if cfg.rope == "mrope":
        positions = _sds((b, 1, 3), jnp.int32,
                         NamedSharding(mesh, P(bspec, None, None)))
    else:
        positions = _sds((b, 1), jnp.int32, tok_sh)
    return cache, token, positions
