"""Deterministic fault injection for the serving engine.

A ``FaultPlan`` is an immutable schedule of fault events keyed on the
ENGINE STEP COUNTER (``Engine.n_steps`` — which also advances on idle
iterations under ``clock="steps"``), consulted by ``Engine.run``
between steps. Four event kinds, each exercising one recovery path:

  pool_shrink   reserve ``n_blocks`` free blocks out of the allocator
                (``BlockAllocator.reserve``) — allocator pressure that
                forces evict-with-recompute-replay and admission
                stalls. ``pool_restore`` gives them back.
  nan           force the jitted step's logits to NaN on the named
                rows for that step — drives the per-row finite-logits
                guard: retry-via-eviction once, then quarantine.
  burst         submit a burst of synthetic requests mid-trace
                (arrival = now) — load-shedding / deadline pressure.
                Bursts are stored as prompt SPECS and materialized
                into fresh ``Request`` objects at fire time, so the
                same plan replayed over a fresh trace reproduces
                byte-identical results (the seed-determinism
                invariant).
  delay         sleep before the step — straggler/jitter injection for
                wall-clock goodput benchmarks (a no-op for the
                deterministic steps clock).

The plan itself holds no mutable firing state: the engine tracks which
events it has consumed, so one ``FaultPlan`` can drive any number of
runs. ``FaultPlan.chaos(seed, ...)`` builds a randomized-but-seeded
mix of all four kinds; the same seed always builds the same plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import Request

#: rid base for burst-injected requests — out of the way of any sane
#: user trace so per-rid bookkeeping never collides.
BURST_RID_BASE = 1_000_000


@dataclasses.dataclass(frozen=True)
class BurstSpec:
    """One synthetic burst request: materialized at fire time."""
    rid: int
    prompt: Tuple[int, ...]
    max_new: int
    ttl: Optional[float] = None         # deadline = fire-time now + ttl

    def materialize(self, now: float) -> Request:
        return Request(
            rid=self.rid, prompt=np.asarray(self.prompt, np.int32),
            max_new=self.max_new, arrival=now,
            deadline=None if self.ttl is None else now + self.ttl)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    step: int
    kind: str                           # see module docstring
    rows: Tuple[int, ...] = ()          # nan
    n_blocks: int = 0                   # pool_shrink / pool_restore
    bursts: Tuple[BurstSpec, ...] = ()  # burst
    delay_s: float = 0.0                # delay

    KINDS = ("nan", "pool_shrink", "pool_restore", "burst", "delay")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"fault kind {self.kind!r}")
        if self.step < 0:
            raise ValueError(f"fault step {self.step}")


class FaultPlan:
    """Immutable, step-indexed fault schedule (see module docstring)."""

    def __init__(self, events: Sequence[FaultEvent] = (), seed: int = 0):
        self.seed = seed
        self.events: Tuple[FaultEvent, ...] = tuple(
            sorted(events, key=lambda e: (e.step, e.KINDS.index(e.kind))))
        self._by_step: Dict[int, List[FaultEvent]] = {}
        for ev in self.events:
            self._by_step.setdefault(ev.step, []).append(ev)

    def events_at(self, step: int) -> List[FaultEvent]:
        return self._by_step.get(step, [])

    def nan_rows(self, step: int) -> Tuple[int, ...]:
        """All rows whose logits are forced non-finite at ``step``."""
        return tuple(r for ev in self.events_at(step) if ev.kind == "nan"
                     for r in ev.rows)

    def has_restore_after(self, step: int) -> bool:
        """True while a pool_restore is still scheduled past ``step`` —
        an apparent admission stall may heal itself, so the engine must
        not diagnose it as permanent yet."""
        return any(ev.kind == "pool_restore" and ev.step > step
                   for ev in self.events)

    @property
    def max_step(self) -> int:
        return max((ev.step for ev in self.events), default=-1)

    def __repr__(self):
        kinds = {}
        for ev in self.events:
            kinds[ev.kind] = kinds.get(ev.kind, 0) + 1
        body = " ".join(f"{k}={v}" for k, v in sorted(kinds.items()))
        return f"FaultPlan(seed={self.seed}, {body or 'empty'})"

    # -- canned chaos ------------------------------------------------------

    @classmethod
    def chaos(cls, seed: int, vocab: int, n_rows: int,
              horizon: int = 40, n_nan: int = 2, shrink_blocks: int = 2,
              n_burst: int = 2, burst_prompt: int = 6, burst_new: int = 3,
              delay_s: float = 0.0) -> "FaultPlan":
        """A randomized-but-seeded mix of every fault kind inside the
        first ``horizon`` engine steps: one pool shrink (restored half
        a horizon later), ``n_nan`` forced-NaN (step, row) pairs with a
        follow-up hit two steps later on one of them (so at least one
        stream exhausts its single retry and quarantines when the
        replay lands back on the same row), one ``n_burst``-request
        arrival burst, and an optional per-step delay."""
        rng = np.random.default_rng(seed)
        events: List[FaultEvent] = []
        lo, hi = max(horizon // 8, 1), max(horizon // 2, 2)
        if shrink_blocks > 0:
            at = int(rng.integers(lo, hi))
            events.append(FaultEvent(step=at, kind="pool_shrink",
                                     n_blocks=shrink_blocks))
            events.append(FaultEvent(step=at + horizon // 2,
                                     kind="pool_restore"))
        for i in range(n_nan):
            step = int(rng.integers(lo, horizon))
            row = int(rng.integers(0, n_rows))
            events.append(FaultEvent(step=step, kind="nan", rows=(row,)))
            if i == 0:
                events.append(FaultEvent(step=step + 2, kind="nan",
                                         rows=(row,)))
        if n_burst > 0:
            specs = tuple(BurstSpec(
                rid=BURST_RID_BASE + i,
                prompt=tuple(int(t) for t in rng.integers(
                    0, vocab, size=burst_prompt)),
                max_new=burst_new) for i in range(n_burst))
            events.append(FaultEvent(step=int(rng.integers(lo, hi)),
                                     kind="burst", bursts=specs))
        if delay_s > 0:
            for step in range(lo, horizon, max(horizon // 4, 1)):
                events.append(FaultEvent(step=step, kind="delay",
                                         delay_s=delay_s))
        return cls(events, seed=seed)
