"""Continuous-batching decode engine: jitted fixed-shape steps over
dynamic request state.

The engine owns R fixed request slots (the batch rows of every jitted
step), a paged KV cache sized in blocks, and a ``Scheduler``. Each
iteration of ``run``:

  1. consult the ``FaultPlan`` (if any): pool-shrink/restore, arrival
     bursts, artificial delays, forced-NaN rows for this step;
  2. expire past-deadline requests, then admit arrived requests into
     free slots (mid-flight — running streams are untouched);
  3. ask the scheduler for this step's batch: prefill rows consume up
     to ``prefill_chunk`` prompt tokens, decode rows ride along with
     one token each (Orca-style fused iteration). Pure-decode steps
     use the C=1 compilation of the same function;
  4. run ONE jitted step: a ``lax.scan`` over the chunk's token
     positions, each position a ``lm.paged_decode_step`` (the segmented
     layer scan + ``flash_decode_paged`` block-table kernel), with
     per-row validity masks — shapes never depend on which requests are
     live, so there are exactly two compilations (C and 1) for the
     whole serving lifetime. The step also reduces a per-row
     finite-logits flag (one ``jnp.isfinite`` all-reduce per position);
  5. quarantine rows that went non-finite (retry once via the
     recompute-replay eviction path, then fail them — neighbors in the
     fused batch never see it), sample greedily at each surviving
     row's last valid position, hand tokens back to the scheduler
     (TTFT / latency bookkeeping, retirement), and loop.

``run`` never raises on a valid trace: unservable submissions come
back ``rejected``, deadline misses ``timeout``, ``max_steps``
exhaustion marks everything unfinished ``timeout`` with partial
``out``, and a permanently-stalled admission queue fails the blocked
head with a block-accounting diagnosis instead of spinning.

Open-loop traces: requests carry ``arrival`` stamps; ``clock="steps"``
replays them against the engine-step counter (deterministic — tests),
``clock="wall"`` against wall time (benchmarks). The engine never
blocks on stragglers: batch composition changes every step.
"""
from __future__ import annotations

import dataclasses
import time
from collections import Counter
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import ArchConfig
from repro.serving.faults import FaultPlan
from repro.serving.paged_cache import (PagedKVCache, init_paged_cache,
                                       paged_cache_axes, table_width)
from repro.serving.scheduler import Request, Scheduler

Array = jax.Array

#: graceful backstop for pathological admit/evict cycles the stall
#: diagnosis cannot prove permanent — finalizes instead of raising.
IDLE_LIMIT = 100_000


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4              # R: concurrent streams (batch rows)
    n_blocks: int = 64            # KV pool size, in blocks
    block_size: int = 16          # tokens per block
    max_len: int = 256            # per-stream cap (prompt + gen - 1)
    prefill_chunk: int = 8        # prompt tokens per prefill step
    max_waiting: Optional[int] = None   # waiting-queue bound (None: ∞)
    shed: str = "reject"          # "reject" | "evict-oldest-waiting"
    max_evictions: int = 8        # evictions before a stream starves
    max_nan_retries: int = 1      # non-finite replays before quarantine


class Engine:
    """Continuous-batching greedy-decode engine over a paged KV cache.

    ``params`` may be dense, SLaB-compressed dense-equivalent, or
    packed (``PackedStack`` leaves — the fused-kernel serving path);
    the paged decode step drives the same segmented layer scan either
    way. Pass ``mesh``/``planner`` (as built by ``serve.py --mesh``) to
    run the steps under a device mesh with planner-placed pools."""

    def __init__(self, cfg: ArchConfig, params: dict,
                 ecfg: EngineConfig = EngineConfig(),
                 mesh=None, planner=None):
        if cfg.family in ("ssm", "hybrid", "audio"):
            raise ValueError(
                f"engine serves KV-attention families; {cfg.family!r} "
                "has no paged cache")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.mesh = mesh
        self.sched = Scheduler(ecfg.n_slots, ecfg.n_blocks,
                               ecfg.block_size, ecfg.max_len,
                               ecfg.prefill_chunk,
                               max_waiting=ecfg.max_waiting,
                               shed=ecfg.shed,
                               max_evictions=ecfg.max_evictions)
        self.paged = init_paged_cache(cfg, ecfg.n_blocks, ecfg.block_size)
        if planner is not None:
            from repro.models.common import is_axes_leaf
            self.paged = jax.device_put(
                self.paged, jax.tree.map(
                    lambda ax, leaf: planner.sharding(ax, leaf.shape),
                    paged_cache_axes(cfg), self.paged,
                    is_leaf=is_axes_leaf))
        self._steps: Dict[int, object] = {}     # chunk C -> jitted step
        self.n_steps = 0

    # -- jitted step -------------------------------------------------------

    def _step_fn(self, c: int):
        """Compile (once per chunk size) the fused prefill/decode step:
        scan ``c`` token positions; row r is live at position t iff
        t < n_valid[r]. Returns the greedy token at each row's LAST
        valid position (prefill completion / decode output), the
        updated pool, and a per-row ALL-positions-finite flag (the
        numerical guard; ``force_nan`` poisons chosen rows — the
        fault-injection hook, all zeros in normal serving)."""
        cfg, params = self.cfg, self.params

        def step(paged: PagedKVCache, tables: Array, lengths: Array,
                 tokens: Array, n_valid: Array, force_nan: Array):
            last0 = jnp.zeros((tokens.shape[0],), jnp.int32)
            ok0 = jnp.ones((tokens.shape[0],), bool)

            def body(carry, xs):
                paged, lens, last, ok = carry
                tok, t = xs
                active = t < n_valid
                logits, paged = lm.paged_decode_step(
                    cfg, params, paged, tables, lens, tok[:, None], active)
                logits = jnp.where(force_nan[:, None, None], jnp.nan,
                                   logits)
                ok = ok & (jnp.all(jnp.isfinite(logits[:, 0]), axis=-1)
                           | ~active)
                nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
                last = jnp.where(t == n_valid - 1, nxt, last)
                return (paged, lens + active, last, ok), None

            xs = (jnp.moveaxis(tokens, 1, 0), jnp.arange(c))
            (paged, _, last, ok), _ = jax.lax.scan(
                body, (paged, lengths, last0, ok0), xs)
            return paged, last, ok

        return jax.jit(step)

    def _run_step(self, tokens: np.ndarray, n_valid: np.ndarray,
                  force_nan: np.ndarray):
        c = tokens.shape[1]
        if c not in self._steps:
            self._steps[c] = self._step_fn(c)
        args = (self.paged,
                jnp.asarray(self.sched.block_table),
                jnp.asarray(self.sched.lengths),
                jnp.asarray(tokens), jnp.asarray(n_valid),
                jnp.asarray(force_nan))
        if self.mesh is not None:
            from repro.runtime.meshctx import use_mesh
            with use_mesh(self.mesh):
                self.paged, last, ok = self._steps[c](*args)
        else:
            self.paged, last, ok = self._steps[c](*args)
        return np.asarray(last), np.asarray(ok)

    # -- fault plumbing ----------------------------------------------------

    def _fire_faults(self, faults: Optional[FaultPlan], fired: set,
                     now: float, injected: List[Request]) -> None:
        """Apply every not-yet-fired plan event due at/by this step."""
        if faults is None:
            return
        for i, ev in enumerate(faults.events):
            if i in fired or ev.step > self.n_steps:
                continue
            fired.add(i)
            if ev.kind == "pool_shrink":
                self.sched.alloc.reserve(ev.n_blocks)
            elif ev.kind == "pool_restore":
                self.sched.alloc.release(
                    ev.n_blocks if ev.n_blocks else None)
            elif ev.kind == "burst":
                for spec in ev.bursts:
                    req = spec.materialize(now)
                    self.sched.submit(req)
                    injected.append(req)
            elif ev.kind == "delay":
                time.sleep(ev.delay_s)
            # "nan" events are consumed by nan_rows() at step-run time

    def _quarantine_nonfinite(self, n_valid: np.ndarray, ok: np.ndarray,
                              now: float) -> None:
        """Handle rows whose logits went non-finite this step: the
        garbage token is never committed; the row is replayed once via
        the recompute eviction path, then failed. Other rows in the
        fused batch are untouched."""
        for row in [r for r in list(self.sched.slots)
                    if n_valid[r] and not ok[r]]:
            req = self.sched.slots[row].req
            if req.n_nan_retries < self.ecfg.max_nan_retries:
                req.n_nan_retries += 1
                self.sched.evict(row)
            else:
                self.sched.fail(row, now=now, error=(
                    f"non-finite logits at step {self.n_steps} "
                    f"(after {req.n_nan_retries} replay(s))"))

    # -- serving loop ------------------------------------------------------

    def _finalize_unfinished(self, status: str, error: str,
                             now: float) -> None:
        """Graceful shutdown: everything still live gets ``status``
        with partial ``out`` — nothing is discarded, nothing raises."""
        for row in list(self.sched.slots):
            req = self.sched._release(row)
            self.sched._finalize(req, status, error=error, now=now)
        for q in (self.sched.waiting, self.sched.pending):
            while q:
                self.sched._finalize(q.pop(0), status, error=error,
                                     now=now)

    def run(self, requests: Sequence[Request], clock: str = "steps",
            max_steps: Optional[int] = None,
            faults: Optional[FaultPlan] = None) -> List[Request]:
        """Serve an open-loop trace to completion. Returns the requests
        (same objects) with ``status``/``out``/``ttft``/``token_times``
        /``finish`` populated — plus any burst requests ``faults``
        injected — and never raises on a valid trace: failures are
        statuses, not exceptions. Arrival order need not be sorted."""
        if clock not in ("steps", "wall"):
            raise ValueError(clock)
        for req in requests:
            self.sched.submit(req)       # unservable -> status rejected
        injected: List[Request] = []
        fired: set = set()
        t0 = time.monotonic()
        idle_guard = 0
        while self.sched.has_work():
            now = (float(self.n_steps) if clock == "steps"
                   else time.monotonic() - t0)
            self._fire_faults(faults, fired, now, injected)
            self.sched.expire(now)
            self.sched.admit(now)
            plan = self.sched.plan_step()
            if plan is None:
                if not self.sched.has_work():
                    break                # expiry drained the trace
                nxt = self.sched.next_arrival()
                idle_guard += 1
                heal = (faults is not None
                        and faults.has_restore_after(self.n_steps))
                if (heal and clock == "wall" and nxt is None
                        and not self.sched.slots):
                    # dead idle on the wall clock never advances
                    # n_steps, so a step-indexed restore would never
                    # fire — fast-forward it instead of sleeping on it
                    for i, ev in enumerate(faults.events):
                        if ev.kind == "pool_restore" and i not in fired:
                            fired.add(i)
                            self.sched.alloc.release(
                                ev.n_blocks if ev.n_blocks else None)
                    continue
                if (nxt is None and not self.sched.slots
                        and self.sched.waiting and not heal):
                    # permanent stall: nothing runs, nothing arrives,
                    # no scheduled restore — fail the blocked head with
                    # the block accounting, keep serving the rest
                    diag = self.sched.diagnose_stall() or (
                        "admission stalled with free blocks")
                    self.sched._finalize(self.sched.waiting.pop(0),
                                         "failed", error=diag, now=now)
                    continue
                if idle_guard > IDLE_LIMIT:
                    diag = self.sched.diagnose_stall()
                    self._finalize_unfinished(
                        "failed", f"idle-loop livelock after "
                        f"{IDLE_LIMIT} iterations"
                        + (f": {diag}" if diag else ""), now)
                    break
                if clock == "steps":
                    self.n_steps += 1
                else:
                    time.sleep(min(1e-3, max(nxt - now, 0.0) if nxt
                                   else 1e-3))
                continue
            idle_guard = 0
            tokens, n_valid, _ = plan
            force_nan = np.zeros((self.sched.n_slots,), bool)
            if faults is not None:
                for row in faults.nan_rows(self.n_steps):
                    force_nan[row] = True
            last, ok = self._run_step(tokens, n_valid, force_nan)
            self.n_steps += 1
            emit_t = (float(self.n_steps) if clock == "steps"
                      else time.monotonic() - t0)
            self._quarantine_nonfinite(n_valid, ok, emit_t)
            self.sched.commit_step(n_valid, last, emit_t)
            if max_steps is not None and self.n_steps >= max_steps:
                self._finalize_unfinished(
                    "timeout", f"max_steps={max_steps} exhausted",
                    emit_t)
                break
        # faults are scoped to the run: any still-reserved blocks come
        # back so the pool-leak invariant (n_free == n_blocks once all
        # streams are terminal) holds at trace end
        self.sched.alloc.release()
        return list(requests) + injected


# ----------------------------------------------------------------------
# Metrics
# ----------------------------------------------------------------------

def summarize(requests: Sequence[Request], wall_s: float) -> dict:
    """Aggregate serving metrics over a completed trace: TTFT and
    inter-token latency percentiles (units = the run's clock),
    aggregate generated tokens/s, per-status counts, and goodput —
    tokens/s counting only tokens of requests that FINISHED (partial
    output of timed-out/failed streams is waste, not goods)."""
    ttfts = [r.ttft for r in requests if r.ttft is not None]
    inter: List[float] = []
    for r in requests:
        ts = r.token_times
        inter.extend(b - a for a, b in zip(ts, ts[1:]))
    n_tok = sum(r.n_generated for r in requests)
    n_good = sum(r.n_generated for r in requests
                 if r.status == "finished")

    def pct(xs, q):
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    return {
        "n_requests": len(requests),
        "n_tokens_out": n_tok,
        "wall_s": wall_s,
        "tokens_per_s": n_tok / wall_s if wall_s > 0 else 0.0,
        "goodput_tokens_per_s": n_good / wall_s if wall_s > 0 else 0.0,
        "statuses": dict(Counter(r.status for r in requests)),
        "ttft": {"p50": pct(ttfts, 50), "p95": pct(ttfts, 95),
                 "p99": pct(ttfts, 99)},
        "per_token_latency": {"p50": pct(inter, 50), "p95": pct(inter, 95),
                              "p99": pct(inter, 99)},
        "n_evictions": sum(r.n_evictions for r in requests),
    }
