"""Continuous-batching decode engine: jitted fixed-shape steps over
dynamic request state.

The engine owns R fixed request slots (the batch rows of every jitted
step), a paged KV cache sized in blocks, and a ``Scheduler``. Each
iteration of ``run``:

  1. admit arrived requests into free slots (mid-flight — running
     streams are untouched);
  2. ask the scheduler for this step's batch: prefill rows consume up
     to ``prefill_chunk`` prompt tokens, decode rows ride along with
     one token each (Orca-style fused iteration). Pure-decode steps
     use the C=1 compilation of the same function;
  3. run ONE jitted step: a ``lax.scan`` over the chunk's token
     positions, each position a ``lm.paged_decode_step`` (the segmented
     layer scan + ``flash_decode_paged`` block-table kernel), with
     per-row validity masks — shapes never depend on which requests are
     live, so there are exactly two compilations (C and 1) for the
     whole serving lifetime;
  4. sample greedily at each row's last valid position, hand tokens
     back to the scheduler (TTFT / latency bookkeeping, retirement),
     and loop.

Open-loop traces: requests carry ``arrival`` stamps; ``clock="steps"``
replays them against the engine-step counter (deterministic — tests),
``clock="wall"`` against wall time (benchmarks). The engine never
blocks on stragglers: batch composition changes every step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models.common import ArchConfig
from repro.serving.paged_cache import (PagedKVCache, init_paged_cache,
                                       paged_cache_axes, table_width)
from repro.serving.scheduler import Request, Scheduler

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_slots: int = 4              # R: concurrent streams (batch rows)
    n_blocks: int = 64            # KV pool size, in blocks
    block_size: int = 16          # tokens per block
    max_len: int = 256            # per-stream cap (prompt + gen - 1)
    prefill_chunk: int = 8        # prompt tokens per prefill step


class Engine:
    """Continuous-batching greedy-decode engine over a paged KV cache.

    ``params`` may be dense, SLaB-compressed dense-equivalent, or
    packed (``PackedStack`` leaves — the fused-kernel serving path);
    the paged decode step drives the same segmented layer scan either
    way. Pass ``mesh``/``planner`` (as built by ``serve.py --mesh``) to
    run the steps under a device mesh with planner-placed pools."""

    def __init__(self, cfg: ArchConfig, params: dict,
                 ecfg: EngineConfig = EngineConfig(),
                 mesh=None, planner=None):
        if cfg.family in ("ssm", "hybrid", "audio"):
            raise ValueError(
                f"engine serves KV-attention families; {cfg.family!r} "
                "has no paged cache")
        self.cfg = cfg
        self.params = params
        self.ecfg = ecfg
        self.mesh = mesh
        self.sched = Scheduler(ecfg.n_slots, ecfg.n_blocks,
                               ecfg.block_size, ecfg.max_len,
                               ecfg.prefill_chunk)
        self.paged = init_paged_cache(cfg, ecfg.n_blocks, ecfg.block_size)
        if planner is not None:
            from repro.models.common import is_axes_leaf
            self.paged = jax.device_put(
                self.paged, jax.tree.map(
                    lambda ax, leaf: planner.sharding(ax, leaf.shape),
                    paged_cache_axes(cfg), self.paged,
                    is_leaf=is_axes_leaf))
        self._steps: Dict[int, object] = {}     # chunk C -> jitted step
        self.n_steps = 0

    # -- jitted step -------------------------------------------------------

    def _step_fn(self, c: int):
        """Compile (once per chunk size) the fused prefill/decode step:
        scan ``c`` token positions; row r is live at position t iff
        t < n_valid[r]. Returns the greedy token at each row's LAST
        valid position (prefill completion / decode output) plus the
        updated pool."""
        cfg, params = self.cfg, self.params

        def step(paged: PagedKVCache, tables: Array, lengths: Array,
                 tokens: Array, n_valid: Array):
            last0 = jnp.zeros((tokens.shape[0],), jnp.int32)

            def body(carry, xs):
                paged, lens, last = carry
                tok, t = xs
                active = t < n_valid
                logits, paged = lm.paged_decode_step(
                    cfg, params, paged, tables, lens, tok[:, None], active)
                nxt = jnp.argmax(logits[:, 0], -1).astype(jnp.int32)
                last = jnp.where(t == n_valid - 1, nxt, last)
                return (paged, lens + active, last), None

            xs = (jnp.moveaxis(tokens, 1, 0), jnp.arange(c))
            (paged, _, last), _ = jax.lax.scan(
                body, (paged, lengths, last0), xs)
            return paged, last

        return jax.jit(step)

    def _run_step(self, tokens: np.ndarray, n_valid: np.ndarray
                  ) -> np.ndarray:
        c = tokens.shape[1]
        if c not in self._steps:
            self._steps[c] = self._step_fn(c)
        args = (self.paged,
                jnp.asarray(self.sched.block_table),
                jnp.asarray(self.sched.lengths),
                jnp.asarray(tokens), jnp.asarray(n_valid))
        if self.mesh is not None:
            from repro.runtime.meshctx import use_mesh
            with use_mesh(self.mesh):
                self.paged, last = self._steps[c](*args)
        else:
            self.paged, last = self._steps[c](*args)
        return np.asarray(last)

    # -- serving loop ------------------------------------------------------

    def run(self, requests: Sequence[Request], clock: str = "steps",
            max_steps: Optional[int] = None) -> List[Request]:
        """Serve an open-loop trace to completion. Returns the requests
        (same objects) with ``out``/``ttft``/``token_times``/``finish``
        populated; arrival order need not be sorted."""
        if clock not in ("steps", "wall"):
            raise ValueError(clock)
        for req in requests:
            self.sched.submit(req)
        t0 = time.monotonic()
        idle_guard = 0
        while self.sched.has_work():
            now = (float(self.n_steps) if clock == "steps"
                   else time.monotonic() - t0)
            self.sched.admit(now)
            plan = self.sched.plan_step()
            if plan is None:
                # nothing runnable: wait for the next arrival
                nxt = self.sched.next_arrival()
                if nxt is None and not self.sched.waiting:
                    raise RuntimeError("scheduler stuck with no work")
                if clock == "steps":
                    self.n_steps += 1
                else:
                    time.sleep(min(1e-3, max(nxt - now, 0.0) if nxt
                                   else 1e-3))
                idle_guard += 1
                if idle_guard > 100_000:
                    raise RuntimeError("engine idle-looped 100k steps")
                continue
            idle_guard = 0
            tokens, n_valid, _ = plan
            last = self._run_step(tokens, n_valid)
            self.n_steps += 1
            emit_t = (float(self.n_steps) if clock == "steps"
                      else time.monotonic() - t0)
            self.sched.commit_step(n_valid, last, emit_t)
            if max_steps is not None and self.n_steps >= max_steps:
                raise RuntimeError(
                    f"engine exceeded max_steps={max_steps} with "
                    f"{len(self.sched.slots)} running / "
                    f"{len(self.sched.waiting)} waiting")
        return list(requests)


# ----------------------------------------------------------------------
# Metrics
# ----------------------------------------------------------------------

def summarize(requests: Sequence[Request], wall_s: float) -> dict:
    """Aggregate serving metrics over a completed trace: TTFT and
    inter-token latency percentiles (units = the run's clock), plus
    aggregate generated tokens/s."""
    ttfts = [r.ttft for r in requests if r.ttft is not None]
    inter: List[float] = []
    for r in requests:
        ts = r.token_times
        inter.extend(b - a for a, b in zip(ts, ts[1:]))
    n_tok = sum(r.n_generated for r in requests)

    def pct(xs, q):
        return float(np.percentile(np.asarray(xs), q)) if xs else 0.0

    return {
        "n_requests": len(requests),
        "n_tokens_out": n_tok,
        "wall_s": wall_s,
        "tokens_per_s": n_tok / wall_s if wall_s > 0 else 0.0,
        "ttft": {"p50": pct(ttfts, 50), "p95": pct(ttfts, 95),
                 "p99": pct(ttfts, 99)},
        "per_token_latency": {"p50": pct(inter, 50), "p95": pct(inter, 95),
                              "p99": pct(inter, 99)},
        "n_evictions": sum(r.n_evictions for r in requests),
    }
