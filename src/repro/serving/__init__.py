"""Continuous-batching serving engine.

The pieces, bottom-up:

  * ``paged_cache`` — the paged/block KV cache: per-layer K/V block
    pools with a per-request block table and a host-side free-list
    allocator (``BlockAllocator``, incl. the ``reserve``/``release``
    fault surface).
  * ``scheduler`` — host-side request scheduler: admits variable-length
    requests mid-flight, interleaves chunked prefill with decode,
    retires finished streams, evicts-with-requeue on block OOM, and
    owns the request lifecycle (statuses, deadlines, load shedding,
    starvation caps).
  * ``faults`` — deterministic fault injection: a seeded ``FaultPlan``
    of step-indexed pool-shrink / forced-NaN / burst / delay events
    the engine consults between steps.
  * ``engine`` — the decode loop: jitted fixed-shape prefill/decode
    steps (``lm.paged_decode_step`` through the segmented layer scan
    and the ``flash_decode_paged`` kernel) driven over the scheduler's
    dynamic request state, replaying open-loop arrival traces, with a
    per-row finite-logits guard quarantining numerically-dead streams.

Entry point: ``Engine.run(requests)`` or ``python -m repro.launch.serve
--engine`` (see docs/serving_engine.md, §Failure modes & recovery).
"""
from repro.serving.engine import Engine, EngineConfig, summarize
from repro.serving.faults import BurstSpec, FaultEvent, FaultPlan
from repro.serving.paged_cache import (BlockAllocator, PagedKVCache,
                                       init_paged_cache, paged_cache_axes)
from repro.serving.scheduler import (STATUSES, TERMINAL, Request,
                                     Scheduler)

__all__ = ["Engine", "EngineConfig", "summarize", "BurstSpec",
           "FaultEvent", "FaultPlan", "BlockAllocator", "PagedKVCache",
           "init_paged_cache", "paged_cache_axes", "STATUSES",
           "TERMINAL", "Request", "Scheduler"]
