"""Continuous-batching serving engine.

The pieces, bottom-up:

  * ``paged_cache`` — the paged/block KV cache: per-layer K/V block
    pools with a per-request block table and a host-side free-list
    allocator (``BlockAllocator``).
  * ``scheduler`` — host-side request scheduler: admits variable-length
    requests mid-flight, interleaves chunked prefill with decode,
    retires finished streams, and evicts-with-requeue on block OOM.
  * ``engine`` — the decode loop: jitted fixed-shape prefill/decode
    steps (``lm.paged_decode_step`` through the segmented layer scan
    and the ``flash_decode_paged`` kernel) driven over the scheduler's
    dynamic request state, replaying open-loop arrival traces.

Entry point: ``Engine.run(requests)`` or ``python -m repro.launch.serve
--engine`` (see docs/serving_engine.md).
"""
from repro.serving.engine import Engine, EngineConfig
from repro.serving.paged_cache import (BlockAllocator, PagedKVCache,
                                       init_paged_cache, paged_cache_axes)
from repro.serving.scheduler import Request, Scheduler

__all__ = ["Engine", "EngineConfig", "BlockAllocator", "PagedKVCache",
           "init_paged_cache", "paged_cache_axes", "Request", "Scheduler"]
