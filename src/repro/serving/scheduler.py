"""Request scheduler for the continuous-batching engine.

All state here is host-side and cheap: the scheduler owns the slot
table (fixed R request slots = the engine's batch rows), the block
allocator, and the per-slot block-table / length mirrors that are
shipped to the jitted steps as plain arrays. Policy:

  admission   arrived requests enter a FIFO waiting queue; free slots
              are filled in queue order (earliest arrival first) any
              time between steps — streams join the running batch
              mid-flight.
  prefill     prompts are consumed in chunks of ``prefill_chunk``
              tokens; while any slot is prefilling, decode rows ride
              along in the same fused step (one token each), so running
              streams keep emitting during admissions.
  retirement  a stream that has produced ``max_new`` tokens retires
              immediately: blocks freed, slot reusable the same step.
  eviction    block-pool OOM evicts the *most recently admitted*
              running request (LIFO victim — earliest arrivals are
              never starved), frees its blocks, and requeues it at the
              front of the waiting queue with ``prompt + generated`` as
              its new prompt (recompute-style preemption: greedy decode
              is deterministic, so the replay continues the stream
              exactly). A request whose worst-case footprint exceeds
              the whole pool is REJECTED at submit time (status
              ``rejected``, never queued), so the highest-priority
              request can always run alone.

Fault tolerance (the lifecycle layer):

  statuses    every request carries a ``status``:
              queued -> running -> finished, with terminal failure
              statuses rejected / timeout / failed / shed. Eviction
              moves a request back to ``queued``. Terminal requests
              keep whatever partial ``out`` they produced.
  deadlines   ``Request.deadline`` is an absolute stamp on the run's
              clock; ``expire(now)`` times out queued *and* running
              requests past it (running rows free their blocks).
  starvation  a request evicted more than ``max_evictions`` times
              fails as starved instead of thrashing forever.
  shedding    ``max_waiting`` bounds the waiting queue; an arrival
              that would overflow it is shed (``shed="reject"``) or
              displaces the oldest waiting entry
              (``shed="evict-oldest-waiting"``).
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.serving.paged_cache import (BlockAllocator, blocks_needed,
                                       table_width)

#: request lifecycle states. queued/running are live; the rest are
#: terminal (a terminal request is never touched again).
STATUSES = ("queued", "running", "finished", "rejected", "timeout",
            "failed", "shed")
TERMINAL = frozenset(STATUSES) - {"queued", "running"}


@dataclasses.dataclass
class Request:
    """One stream: a prompt and a greedy-decode budget."""
    rid: int
    prompt: np.ndarray                  # (P,) int32 token ids
    max_new: int
    arrival: float = 0.0
    deadline: Optional[float] = None    # absolute, on the run's clock

    # filled by the engine ------------------------------------------------
    status: str = "queued"
    error: Optional[str] = None         # terminal diagnostic (failures)
    out: List[int] = dataclasses.field(default_factory=list)
    ttft: Optional[float] = None        # first-token time - arrival
    finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)
    n_evictions: int = 0
    n_nan_retries: int = 0              # non-finite-logits replays used

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if len(self.prompt) == 0:
            raise ValueError(f"request {self.rid}: empty prompt")
        if self.max_new < 1:
            raise ValueError(f"request {self.rid}: max_new={self.max_new}")

    @property
    def n_generated(self) -> int:
        return len(self.out)

    @property
    def done(self) -> bool:
        return self.n_generated >= self.max_new

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL

    def serve_prompt(self) -> np.ndarray:
        """Prompt to (re)prefill: original prompt plus everything
        generated so far (recompute preemption continues the stream)."""
        if not self.out:
            return self.prompt
        return np.concatenate(
            [self.prompt, np.asarray(self.out, np.int32)])

    def max_cached_tokens(self) -> int:
        """Worst-case cache footprint: every fed token. The final
        generated token is emitted but never fed back."""
        return len(self.prompt) + self.max_new - 1


@dataclasses.dataclass
class _Slot:
    req: Request
    blocks: List[int]
    n_prefilled: int                    # serve_prompt tokens already fed
    admit_seq: int                      # LIFO eviction order
    phase: str                          # "prefill" | "decode"
    next_token: int = -1                # decode: last sampled, to feed


class Scheduler:
    def __init__(self, n_slots: int, n_blocks: int, block_size: int,
                 max_len: int, prefill_chunk: int = 8,
                 max_waiting: Optional[int] = None, shed: str = "reject",
                 max_evictions: int = 8):
        if n_slots < 1 or n_blocks < 1 or prefill_chunk < 1:
            raise ValueError((n_slots, n_blocks, prefill_chunk))
        if shed not in ("reject", "evict-oldest-waiting"):
            raise ValueError(f"shed={shed!r}")
        if max_waiting is not None and max_waiting < 1:
            raise ValueError(f"max_waiting={max_waiting}")
        self.n_slots = n_slots
        self.block_size = block_size
        self.max_len = max_len
        self.n_bt = table_width(max_len, block_size)
        self.prefill_chunk = prefill_chunk
        self.max_waiting = max_waiting
        self.shed = shed
        self.max_evictions = max_evictions
        self.alloc = BlockAllocator(n_blocks)
        self.pending: List[Request] = []         # submitted, not arrived
        self.waiting: List[Request] = []         # arrived, no slot
        self.slots: Dict[int, _Slot] = {}        # row -> slot state
        self.block_table = np.zeros((n_slots, self.n_bt), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self._admit_seq = 0
        self.n_evictions = 0

    # -- lifecycle ---------------------------------------------------------

    def _finalize(self, req: Request, status: str,
                  error: Optional[str] = None,
                  now: Optional[float] = None) -> Request:
        assert status in TERMINAL, status
        req.status = status
        req.error = error
        if now is not None:
            req.finish = now
        return req

    # -- submission / admission ------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request. Unservable requests are REJECTED with
        ``status="rejected"`` (never queued) instead of raising, so one
        bad request cannot kill a trace. Returns True iff queued."""
        if req.terminal:
            return False
        need = req.max_cached_tokens()
        if need > self.max_len:
            self._finalize(req, "rejected", error=(
                f"{need} cached tokens exceeds engine "
                f"max_len={self.max_len}"))
            return False
        if blocks_needed(need, self.block_size) > self.alloc.n_blocks:
            self._finalize(req, "rejected", error=(
                f"needs {blocks_needed(need, self.block_size)} blocks, "
                f"pool has {self.alloc.n_blocks} — cannot ever run"))
            return False
        req.status = "queued"
        bisect.insort(self.pending, req, key=lambda r: r.arrival)
        return True

    def expire(self, now: float) -> List[Request]:
        """Time out every live request whose deadline has passed:
        queued requests leave their queue, running requests free their
        blocks and slot. Partial ``out`` is kept. Returns the newly
        timed-out requests."""
        def late(r: Request) -> bool:
            return r.deadline is not None and now >= r.deadline

        timed: List[Request] = []
        for q in (self.pending, self.waiting):
            for req in [r for r in q if late(r)]:
                q.remove(req)
                timed.append(self._finalize(
                    req, "timeout", now=now,
                    error=f"deadline {req.deadline} passed at {now}"))
        for row in [r for r in self.slots if late(self.slots[r].req)]:
            req = self._release(row)
            timed.append(self._finalize(
                req, "timeout", now=now,
                error=f"deadline {req.deadline} passed at {now}"))
        return timed

    def _shed_overflow(self) -> List[Request]:
        """Enforce the ``max_waiting`` bound on the post-admission
        backlog: overflow is shed from the BACK (newest arrivals) under
        ``shed="reject"``, from the FRONT (longest waiting) under
        ``"evict-oldest-waiting"``. Returns the shed requests."""
        shed: List[Request] = []
        if self.max_waiting is None:
            return shed
        while len(self.waiting) > self.max_waiting:
            if self.shed == "reject":
                shed.append(self._finalize(self.waiting.pop(), "shed",
                            error=(f"waiting queue full "
                                   f"(max_waiting={self.max_waiting})")))
            else:
                shed.append(self._finalize(self.waiting.pop(0), "shed",
                            error=(f"displaced: oldest of an "
                                   f"over-full waiting queue "
                                   f"(max_waiting={self.max_waiting})")))
        return shed

    def admit(self, now: float) -> List[int]:
        """Move arrived requests into free slots; shed waiting-queue
        overflow. Returns filled rows."""
        while self.pending and self.pending[0].arrival <= now:
            self.waiting.append(self.pending.pop(0))
        filled = []
        for row in range(self.n_slots):
            if not self.waiting:
                break
            if row in self.slots:
                continue
            # admission control: only admit when the full prompt fits in
            # currently-free blocks — an admit that would immediately
            # OOM just evicts itself back (thrash)
            nxt = self.waiting[0]
            if (blocks_needed(len(nxt.serve_prompt()), self.block_size)
                    > self.alloc.n_free):
                break
            req = self.waiting.pop(0)
            req.status = "running"
            self.slots[row] = _Slot(req=req, blocks=[], n_prefilled=0,
                                    admit_seq=self._admit_seq,
                                    phase="prefill")
            self._admit_seq += 1
            self.block_table[row, :] = 0
            self.lengths[row] = 0
            filled.append(row)
        self._shed_overflow()
        return filled

    # -- block accounting -------------------------------------------------

    def _capacity(self, row: int) -> int:
        return len(self.slots[row].blocks) * self.block_size

    def _grow(self, row: int, target_tokens: int) -> bool:
        """Allocate blocks until ``row`` can cache ``target_tokens``;
        on pool OOM evict LIFO victims (never ``row`` itself unless it
        IS the newest). Returns False if ``row`` was evicted instead."""
        slot = self.slots[row]
        while self._capacity(row) < target_tokens:
            n_need = blocks_needed(target_tokens, self.block_size) \
                - len(slot.blocks)
            got = self.alloc.alloc(n_need)
            if got is not None:
                for b in got:
                    self.block_table[row, len(slot.blocks)] = b
                    slot.blocks.append(b)
                return True
            victim = max(self.slots, key=lambda r: self.slots[r].admit_seq)
            self.evict(victim)
            if victim == row:
                return False
        return True

    def _release(self, row: int) -> Request:
        """Free ``row``'s blocks and slot; caller sets the status."""
        slot = self.slots.pop(row)
        self.alloc.free(slot.blocks)
        self.block_table[row, :] = 0
        self.lengths[row] = 0
        return slot.req

    def evict(self, row: int) -> None:
        """Preempt ``row``: free its blocks, requeue front-of-line.
        A request past its eviction budget is finalized as starved
        (status ``failed``) instead of requeued — N replays that never
        stick are thrash, not progress."""
        req = self._release(row)
        req.n_evictions += 1
        self.n_evictions += 1
        if req.n_evictions > self.max_evictions:
            self._finalize(req, "failed", error=(
                f"starved: evicted {req.n_evictions} times "
                f"(max_evictions={self.max_evictions})"))
            return
        # decode rows hold a sampled-but-unfed token: fold it into the
        # replayed prompt so nothing is lost (it was already emitted)
        req.status = "queued"
        self.waiting.insert(0, req)

    def fail(self, row: int, error: str,
             now: Optional[float] = None) -> Request:
        """Quarantine ``row``: free its blocks, finalize as failed.
        Partial ``out`` survives; neighbors are untouched."""
        return self._finalize(self._release(row), "failed", error=error,
                              now=now)

    def retire(self, row: int, now: float) -> Request:
        return self._finalize(self._release(row), "finished", now=now)

    # -- step planning ----------------------------------------------------

    def has_work(self) -> bool:
        return bool(self.slots or self.waiting or self.pending)

    def next_arrival(self) -> Optional[float]:
        return self.pending[0].arrival if self.pending else None

    def diagnose_stall(self) -> Optional[str]:
        """Why the head of the waiting queue cannot be admitted —
        ``None`` when it could be (or nothing waits)."""
        if not self.waiting:
            return None
        nxt = self.waiting[0]
        need = blocks_needed(len(nxt.serve_prompt()), self.block_size)
        if need <= self.alloc.n_free:
            return None
        return (f"rid={nxt.rid} blocked: prompt of "
                f"{len(nxt.serve_prompt())} tokens needs {need} blocks, "
                f"{self.alloc.n_free}/{self.alloc.n_blocks} free"
                + (f" ({self.alloc.n_reserved} reserved)"
                   if self.alloc.n_reserved else ""))

    def plan_step(self) -> Optional[Tuple[np.ndarray, np.ndarray, bool]]:
        """Build this step's fixed-shape batch.

        Returns (tokens (R, C), n_valid (R,), any_prefill) or None when
        no slot can run. Prefill rows consume up to ``prefill_chunk``
        prompt tokens; decode rows ride along with one token
        (``any_prefill`` False means every row is decode — the engine
        uses its C=1 step). Rows the allocator had to evict drop out of
        the batch (n_valid 0)."""
        any_prefill = any(s.phase == "prefill" for s in self.slots.values())
        c = self.prefill_chunk if any_prefill else 1
        tokens = np.zeros((self.n_slots, c), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        # LIFO-victim eviction: grow highest-priority rows first so a
        # victim's freed blocks serve earlier arrivals, not later ones
        rows = sorted(self.slots, key=lambda r: self.slots[r].admit_seq)
        for row in rows:
            if row not in self.slots:        # evicted by an earlier grow
                continue
            slot = self.slots[row]
            if slot.phase == "prefill":
                prompt = slot.req.serve_prompt()
                take = min(c, len(prompt) - slot.n_prefilled)
                if not self._grow(row, self.lengths[row] + take):
                    continue
                tokens[row, :take] = prompt[
                    slot.n_prefilled:slot.n_prefilled + take]
                n_valid[row] = take
            else:
                if not self._grow(row, self.lengths[row] + 1):
                    continue
                tokens[row, 0] = slot.next_token
                n_valid[row] = 1
        if not n_valid.any():
            return None
        return tokens, n_valid, any_prefill

    def commit_step(self, n_valid: np.ndarray, sampled: np.ndarray,
                    now: float) -> List[Request]:
        """Advance slot state after a step. ``sampled`` (R,) is each
        row's greedy token at its last valid position. Returns retired
        requests."""
        retired = []
        for row in list(self.slots):
            took = int(n_valid[row])
            if not took:
                continue
            slot = self.slots[row]
            self.lengths[row] += took
            if slot.phase == "prefill":
                slot.n_prefilled += took
                if slot.n_prefilled < len(slot.req.serve_prompt()):
                    continue                 # more prompt to feed
                slot.phase = "decode"
            tok = int(sampled[row])
            req = slot.req
            if req.ttft is None:
                req.ttft = now - req.arrival
            req.out.append(tok)
            req.token_times.append(now)
            slot.next_token = tok
            if req.done:
                retired.append(self.retire(row, now))
        return retired
