"""Paged KV cache: fixed-size blocks, per-request block tables, and a
host-side free-list allocator.

Layout. One global pool per layer holds every request's K/V in
fixed-size blocks:

    k, v     (L, n_blocks, block_size, KV, dh)      cfg.dtype | int8
    k_scale  (L, n_blocks, block_size, KV) f32      int8 mode only

A request's cache is the *logical* concatenation of the blocks its
block-table row names: ``block_tables[r, j]`` is the physical block
holding tokens ``[j*block_size, (j+1)*block_size)`` of request ``r``.
Blocks are allocated on demand as a stream grows and returned to the
free list when it retires (or is evicted) — fragmentation-free KV
memory at block granularity, the vLLM paging idea.

Device state is only the pools. Block tables and lengths are small
host-side numpy arrays owned by the scheduler and shipped as ordinary
jit arguments each step, so allocation/eviction never touches device
state and the step functions stay pure.

Writes go through ``paged_write``: a flat scatter at
``block_id * block_size + offset`` with ``mode="drop"`` so inactive
rows (idle slots, exhausted prefill rows) write nowhere. Reads go
through the ``flash_decode_paged`` kernel, whose BlockSpec index maps
consume the block table as a scalar-prefetch operand.
"""
from __future__ import annotations

from typing import List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig

Array = jax.Array


class PagedKVCache(NamedTuple):
    """Stacked per-layer block pools (exactly one pool per attention
    layer; families without KV attention don't page)."""
    k: Array                        # (L, n_blocks, bs, KV, dh)
    v: Array                        # (L, n_blocks, bs, KV, dh)
    k_scale: Optional[Array] = None   # (L, n_blocks, bs, KV) f32, int8 only
    v_scale: Optional[Array] = None

    # indexed from the END so the properties are correct both for the
    # stacked (L, n_blocks, bs, KV, dh) layout and for a single-layer
    # (n_blocks, bs, KV, dh) slice riding a layer scan
    @property
    def n_blocks(self) -> int:
        return self.k.shape[-4]

    @property
    def block_size(self) -> int:
        return self.k.shape[-3]


def init_paged_cache(cfg: ArchConfig, n_blocks: int,
                     block_size: int) -> PagedKVCache:
    if cfg.family in ("ssm", "hybrid", "audio"):
        raise ValueError(
            f"paged KV serving needs a KV-attention family, not "
            f"{cfg.family!r} (SSM state is O(1) — it doesn't page)")
    shp = (cfg.n_layers, n_blocks, block_size, cfg.n_kv, cfg.d_head)
    if cfg.kv_quant:
        sshp = shp[:-1]
        return PagedKVCache(jnp.zeros(shp, jnp.int8),
                            jnp.zeros(shp, jnp.int8),
                            jnp.zeros(sshp, jnp.float32),
                            jnp.zeros(sshp, jnp.float32))
    return PagedKVCache(jnp.zeros(shp, cfg.dtype), jnp.zeros(shp, cfg.dtype))


def paged_cache_axes(cfg: ArchConfig) -> PagedKVCache:
    """Logical axes for planner placement (runtime.sharding rules):
    blocks are never sharded — any request may own any block, so a
    block dim split would scatter one stream across shards — while the
    KV-head dim TP-shards over "model" when it divides (each shard
    serves its heads' pool; the flash-decode grid is per-kv-head)."""
    scale_ax = (("layers", "kv_blocks", None, "kv_heads")
                if cfg.kv_quant else None)
    ax = ("layers", "kv_blocks", None, "kv_heads", None)
    return PagedKVCache(ax, ax, scale_ax, scale_ax)


def paged_write(pool: Array, new: Array, block_ids: Array, offsets: Array,
                active: Array) -> Array:
    """Scatter one token per request row into a (single-layer) pool.

    pool (n_blocks, bs, KV, dh) | (n_blocks, bs, KV); new (R, KV, dh) |
    (R, KV); block_ids/offsets (R,) int32; active (R,) bool. Inactive
    rows are routed out of bounds and dropped by the scatter."""
    n_blocks, bs = pool.shape[0], pool.shape[1]
    flat = pool.reshape((n_blocks * bs,) + pool.shape[2:])
    idx = jnp.where(active, block_ids * bs + offsets, n_blocks * bs)
    flat = flat.at[idx].set(new.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


class BlockAllocator:
    """Host-side free list over the pool's physical block ids.

    LIFO reuse keeps recently-freed blocks hot. The allocator is
    all-or-nothing: ``alloc(n)`` either returns n block ids or None
    (caller decides to evict/queue) — no partial grants to unwind.

    ``reserve(n)``/``release()`` take free blocks out of circulation
    and put them back — the fault-injection surface for allocator
    pressure (``serving/faults.py`` pool-shrink events). Reserved
    blocks are neither free nor allocated; ``release()`` must be
    called before the end-of-trace leak check ``n_free == n_blocks``
    holds."""

    def __init__(self, n_blocks: int):
        self.n_blocks = n_blocks
        self._free: List[int] = list(range(n_blocks - 1, -1, -1))
        self._reserved: List[int] = []

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_reserved(self) -> int:
        return len(self._reserved)

    def reserve(self, n: int) -> int:
        """Pull up to ``n`` free blocks out of circulation (pool-shrink
        fault). Returns how many were actually reserved — never more
        than are free, so live streams keep their blocks."""
        if n < 0:
            raise ValueError(f"reserve({n})")
        take = min(n, len(self._free))
        self._reserved.extend(self._free[len(self._free) - take:])
        del self._free[len(self._free) - take:]
        return take

    def release(self, n: Optional[int] = None) -> int:
        """Return ``n`` (default: all) reserved blocks to the free
        list. Returns how many came back."""
        give = len(self._reserved) if n is None else min(
            n, len(self._reserved))
        self._free.extend(self._reserved[len(self._reserved) - give:])
        del self._reserved[len(self._reserved) - give:]
        return give

    def alloc(self, n: int) -> Optional[List[int]]:
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        got = self._free[-n:][::-1] if n else []
        del self._free[len(self._free) - n:]
        return got

    def free(self, ids: List[int]) -> None:
        for b in ids:
            if not (0 <= b < self.n_blocks):
                raise ValueError(f"free of out-of-range block {b}")
        if set(ids) & set(self._free):
            raise ValueError(f"double free: {set(ids) & set(self._free)}")
        self._free.extend(ids)


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-n_tokens // block_size)


def table_width(max_len: int, block_size: int) -> int:
    """Block-table columns needed to address ``max_len`` tokens."""
    return max(blocks_needed(max_len, block_size), 1)
