#!/usr/bin/env bash
# Fast verify tiers (PYTHONPATH handled for you):
#
#   scripts/tier1.sh            # fast tier: everything except @slow
#                               # (subprocess dry-runs, training loops)
#   scripts/tier1.sh core       # kernel/core edit loop (~1 min): SLaB
#                               # decomposition, Pallas kernels, taps,
#                               # flash-decode, HLO analysis
#   scripts/tier1.sh pipeline   # compression-policy loop: compressor
#                               # registry, plans, layer-wise pipeline,
#                               # taps (mixed-method e2e stays @slow)
#   scripts/tier1.sh packed     # packed-serving loop: variant-tagged
#                               # formats, per-variant kernels (incl.
#                               # ELL gather-matmul), heterogeneous
#                               # stacks, segmented-scan serving, e2e
#                               # packed forward/decode (full-depth
#                               # trace-count cases stay @slow)
#   scripts/tier1.sh moe        # expert-packed MoE loop: K_max
#                               # bucketing, grouped-expert kernels,
#                               # dense-member fallbacks, MoE/hybrid
#                               # shared-block parity (engine replay +
#                               # deepseek geometry stay @slow)
#   scripts/tier1.sh engine     # serving-engine loop: paged KV
#                               # cache + block allocator, request
#                               # scheduler policy, flash_decode
#                               # (contiguous + paged), engine e2e
#                               # traces vs greedy_decode
#   scripts/tier1.sh faults     # fault-tolerance loop: request
#                               # lifecycle statuses, deadlines, load
#                               # shedding, starvation caps, the
#                               # finite-logits guard, and the chaos
#                               # harness (FaultPlan) e2e recovery
#                               # traces incl. seed determinism +
#                               # block-leak teardown checks
#   scripts/tier1.sh allocator  # budget-allocator loop: water-filling
#                               # solver, @auto plans, plan DSL
#                               # round-trips, cross-variant kernel
#                               # parity sweep
#   scripts/tier1.sh distributed # tensor-parallel packed-serving loop:
#                               # per-variant Planner specs, segment
#                               # pre-slicing, decode parity under a
#                               # real 2-device mesh (2 fake CPU
#                               # devices; the 8-device subprocess
#                               # suite stays @slow in
#                               # tests/test_distributed.py)
#   scripts/tier1.sh <pytest args...>   # anything else passes through
#
# The full suite (the tier-1 gate, incl. @slow) stays:
#   PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [ "${1:-}" = "core" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_slab_core.py tests/test_substrates.py \
        tests/test_kernels.py tests/test_flash_decode.py \
        tests/test_taps.py tests/test_perf_features.py "$@"
fi

if [ "${1:-}" = "pipeline" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_plan.py tests/test_pipeline.py tests/test_taps.py "$@"
fi

if [ "${1:-}" = "packed" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_kernels.py tests/test_packed_serving.py \
        tests/test_hetero_packing.py tests/test_variant_parity.py \
        tests/test_ell_kernels.py tests/test_segmented_scan.py "$@"
fi

if [ "${1:-}" = "moe" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_expert_packing.py tests/test_models.py "$@"
fi

if [ "${1:-}" = "distributed" ]; then
    shift
    exec env XLA_FLAGS="--xla_force_host_platform_device_count=2" \
        python -m pytest -q -m "not slow" \
        tests/test_packed_sharding.py "$@"
fi

if [ "${1:-}" = "engine" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_serving_engine.py tests/test_flash_decode.py "$@"
fi

if [ "${1:-}" = "faults" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_serving_faults.py "$@"
fi

if [ "${1:-}" = "allocator" ]; then
    shift
    exec python -m pytest -q -m "not slow" \
        tests/test_allocator.py tests/test_plan_roundtrip.py \
        tests/test_plan.py tests/test_variant_parity.py "$@"
fi
exec python -m pytest -q -m "not slow" "$@"
