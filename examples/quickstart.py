"""Quickstart: decompose one weight matrix with SLaB and inspect every
piece of the paper's Eq. (1): W ≈ W_S + W_L ⊙ W_B.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import packing, scores
from repro.core.apply import slab_linear
from repro.core.slab import (SLaBConfig, compression_ratio, keep_fraction,
                             reconstruct, slab_decompose)
from repro.kernels import ops

# A fake "linear layer" weight and its calibration activations.
d_out, d_in = 512, 1024
w = jax.random.normal(jax.random.PRNGKey(0), (d_out, d_in)) * 0.02
x_cal = jax.random.normal(jax.random.PRNGKey(1), (256, d_in))
act_norms = scores.act_col_norms(x_cal)          # ‖X_j‖₂ (Wanda stats)

# --- decompose at 50% compression (paper's headline setting) ----------
cfg = SLaBConfig(cr=0.5, bits=16, iters=20)
dec = slab_decompose(w, act_norms, cfg)

print(f"keep fraction (Eq. 10): {keep_fraction(0.5, 16, d_out, d_in):.4f}")
print(f"nnz(W_S)/total:         {float(jnp.mean(dec.w_s != 0)):.4f}")
print(f"achieved CR (Eq. 9):    {compression_ratio(dec):.4f}")
print(f"W_B values:             {jnp.unique(dec.w_b)}")
print(f"W_L factors >= 0:       u {bool(jnp.all(dec.u >= 0))}, "
      f"v {bool(jnp.all(dec.v >= 0))}   (Prop. 2)")

err = float(jnp.linalg.norm(w - reconstruct(dec)) / jnp.linalg.norm(w))
print(f"relative recon error:   {err:.4f}")

# --- vs pruning alone at the same storage budget ----------------------
from repro.core import baselines
w_wanda = baselines.wanda_prune(w, act_norms, 0.5)
err_w = float(jnp.linalg.norm(w - w_wanda) / jnp.linalg.norm(w))
print(f"wanda@same budget:      {err_w:.4f}  "
      f"(SLaB recovers {100 * (1 - err / err_w):.1f}% of its error)")

# --- the same decomposition through the compressor registry -----------
# (core.compressor is the pluggable API the compression pipeline uses;
#  plans route each linear to a registered compressor by name)
from repro.core import compressor
print(f"registered compressors: {compressor.available()}")
slab_c = compressor.get("slab", cfg)
cl = slab_c.compress(w, compressor.LinearStats(norms=act_norms))
print(f"registry slab:          measured CR {cl.cr:.4f}, "
      f"dense-equivalent matches: "
      f"{bool(jnp.allclose(cl.dense, reconstruct(dec)))}")

# --- serve it ----------------------------------------------------------
x = jax.random.normal(jax.random.PRNGKey(2), (8, d_in))
y_ref = x @ reconstruct(dec).T
y_jnp = slab_linear(x, dec)                          # XLA path
pk = packing.pack_decomposition(dec)                 # bit-packed form
y_kern = ops.slab_linear_kernel(x, pk, bm=8, bn=128, bk=256,
                                interpret=True)      # Pallas kernel
print(f"XLA path max err:       {float(jnp.max(jnp.abs(y_jnp - y_ref))):.2e}")
print(f"Pallas kernel max err:  {float(jnp.max(jnp.abs(y_kern - y_ref))):.2e}")
print(f"packed B matrix:        {pk.b_packed.shape} uint32 "
      f"(16x smaller than bf16)")
