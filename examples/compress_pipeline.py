"""Full layer-wise compression pipeline on any assigned architecture:
the SparseGPT/Wanda protocol with SLaB, per-layer error reporting, and
a method comparison at matched compression ratio.

    PYTHONPATH=src python examples/compress_pipeline.py --arch deepseek_moe_16b
    PYTHONPATH=src python examples/compress_pipeline.py --arch mamba2_1_3b --cr 0.7
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import compressor
from repro.core.pipeline import compress_model, linear_paths
from repro.core.plan import plan_for_method
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import softmax_xent


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2_7b",
                    choices=configs.ARCH_IDS + configs.EXTRA_IDS)
    ap.add_argument("--cr", type=float, default=0.5)
    ap.add_argument("--pattern", default=None)
    ap.add_argument("--iters", type=int, default=8)
    args = ap.parse_args()

    cfg = configs.get(args.arch, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name} ({cfg.family}): {lm.param_count(cfg)/1e6:.2f}M "
          f"params; compressible linears/layer: {linear_paths(cfg)}")

    cal = calibration_batch(cfg.vocab, n_seq=8, seq_len=64)

    def quality(p):
        corpus = SyntheticCorpus(cfg.vocab, seed=0)
        tot = 0.0
        for batch in corpus.eval_batches(3, 8, 64):
            x = jnp.asarray(batch["inputs"])
            if cfg.input_mode == "embeds" and cfg.family == "audio":
                x = jax.random.normal(jax.random.PRNGKey(0),
                                      (8, 64, cfg.d_model))
            logits, _ = lm.forward(cfg, p, x)
            tot += float(softmax_xent(logits,
                                      jnp.asarray(batch["labels"])))
        return float(np.exp(tot / 3))

    print(f"dense ppl (untrained: ~ln V baseline): {quality(params):.2f}")
    print(f"registered compressors: {compressor.available()}")
    # every registered method runs on every family: per-need Hessians
    # (sparsegpt, hassle) come from the same taps
    for method in ("slab", "wanda", "sparsegpt", "hassle", "magnitude"):
        scfg = SLaBConfig(cr=args.cr, pattern=args.pattern,
                          iters=args.iters)
        new, stats = compress_model(cfg, params, cal,
                                    plan=plan_for_method(method, scfg),
                                    progress=lambda s: None)
        # relative activation-weighted reconstruction error: err_after
        # against the zero-approximation baseline err_before
        rel = [s.err_after / s.err_before for s in stats if s.err_before]
        cr_meas = np.mean([s.cr for s in stats])
        print(f"{method:10s} CR={cr_meas:.1%} ppl={quality(new):8.2f} "
              f"rel-recon-err={np.mean(rel):.4f}")


if __name__ == "__main__":
    main()
