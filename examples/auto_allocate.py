"""Sensitivity-driven per-layer CR allocation in ~40 lines.

One streaming calibration pass taps every layer's activation norms;
the allocator samples each linear's CR->error frontier from them,
water-fills a global budget, and emits a concrete CompressionPlan the
normal pipeline executes from the SAME statistics — no second pass.

  PYTHONPATH=src python examples/auto_allocate.py
"""
import jax
import jax.numpy as jnp

from repro import configs
from repro.core.allocator import allocate_plan
from repro.core.pipeline import compress_model
from repro.data import calibration_batch
from repro.models import lm


def main():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=8, seq_len=64)

    # probe + solve: per-(layer, path) CRs meeting a 0.5 global budget
    alloc = allocate_plan(cfg, params, cal, budget=0.5,
                          template="*=slab@iters=4")
    print(alloc.table())

    # compress from the probe's statistics — zero extra forwards
    new, stats = compress_model(cfg, params, None, plan=alloc.plan,
                                stats=alloc.stats)

    # the uniform plan at the same budget, from the same stats
    _, uni = compress_model(cfg, params, None,
                            plan="*=slab@cr=0.5,iters=4",
                            stats=alloc.stats)
    err_a = sum(s.err_after for s in stats)
    err_u = sum(s.err_after for s in uni)
    print(f"\nsummed err_after: allocated {err_a:.4g} vs uniform "
          f"{err_u:.4g} ({100 * (err_u - err_a) / err_u:.1f}% better)")

    # the one-liner equivalent: an @auto plan allocates internally
    new2, _ = compress_model(cfg, params, cal,
                             plan="*=slab@auto,iters=4; budget=0.5")
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new2, t)
    print("@auto plan forward ok:", bool(jnp.all(jnp.isfinite(logits))))


if __name__ == "__main__":
    main()
