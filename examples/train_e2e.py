"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
on the synthetic corpus with the full production stack — microbatched
grad accumulation, remat, checkpointing, watchdog — then SLaB-compress
the result and report the quality delta.

    PYTHONPATH=src python examples/train_e2e.py [--steps 300] [--tiny]

(--tiny shrinks the model for CI-speed smoke runs; the default builds a
~100M-param llama-geometry model. On one CPU this takes a while — the
same entrypoint scales to the pod meshes via --data-par/--model-par.)
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.pipeline import compress_model
from repro.core.plan import CalibrationSpec, plan_for_method
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.launch.train import train
from repro.models import lm
from repro.models.common import ArchConfig, softmax_xent


def model_100m() -> ArchConfig:
    # llama geometry, ~100M params: 12L, d=768, 12H, ff=2048, vocab=8192
    return configs.get("llama2_7b").with_(
        name="llama-100m", n_layers=12, d_model=768, n_heads=12, n_kv=4,
        d_head=64, d_ff=2048, vocab=8192, q_chunk=128, dtype=jnp.float32)


def eval_ppl(cfg, params, n=4, b=8, s=128):
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    tot = 0.0
    for batch in corpus.eval_batches(n, b, s):
        logits, _ = lm.forward(cfg, params, jnp.asarray(batch["inputs"]))
        tot += float(softmax_xent(logits, jnp.asarray(batch["labels"])))
    return float(np.exp(tot / n))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/slab_train_e2e")
    args = ap.parse_args()

    cfg = model_100m()
    if args.tiny:
        cfg = cfg.with_(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                        d_head=32, d_ff=256, vocab=512)
    print(f"model: {cfg.name}  params={lm.param_count(cfg)/1e6:.1f}M")

    # --- monkey-wire the custom config through the launch driver -------
    import repro.configs as cmod
    import types
    mod = types.ModuleType("repro.configs.custom_e2e")
    mod.FULL = cfg
    mod.SMOKE = cfg
    import sys
    sys.modules["repro.configs.custom_e2e"] = mod

    state, losses = train(
        "custom_e2e", smoke=True, steps=args.steps,
        batch=8 if args.tiny else 16, seq=128 if args.tiny else 256,
        ckpt_dir=args.ckpt_dir, microbatches=2, remat="nothing",
        lr=3e-3, log_every=20, ckpt_every=100)
    params = jax.tree.map(lambda x: x.astype(jnp.float32),
                          state["params"])
    ppl_dense = eval_ppl(cfg, params)
    print(f"dense ppl: {ppl_dense:.3f}  (uniform would be {cfg.vocab})")

    # stream the calibration set through the tap capture in chunks of 4
    # sequences (statistics accumulate across chunks)
    cal = CalibrationSpec(calibration_batch(cfg.vocab, n_seq=8,
                                            seq_len=128), batch_size=4)
    for method in ("slab", "wanda"):
        plan = plan_for_method(method, SLaBConfig(cr=0.5, iters=8))
        new, _ = compress_model(cfg, params, cal, plan=plan)
        print(f"{method}@CR50 ppl: {eval_ppl(cfg, new):.3f}")


if __name__ == "__main__":
    main()
