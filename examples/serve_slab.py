"""Serve a SLaB-compressed model with batched requests.

    PYTHONPATH=src python examples/serve_slab.py

Flow: init model -> layer-wise SLaB compression (calibrated) -> batched
greedy decoding with KV cache; reports tokens/s and the weight-stream
byte reduction the compressed format gives a memory-bound decoder.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import packing
from repro.core.pipeline import compress_model, linear_paths
from repro.core.slab import SLaBConfig, slab_decompose
from repro.data import SyntheticCorpus, calibration_batch
from repro.launch.serve import greedy_decode
from repro.models import lm


def main():
    cfg = configs.get("llama2_7b", smoke=True)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    print(f"{cfg.name}: {lm.param_count(cfg)/1e6:.2f}M params")

    cal = calibration_batch(cfg.vocab, n_seq=8, seq_len=64)
    t0 = time.monotonic()
    # plan API: one catch-all rule (equivalent to method="slab" sugar)
    params_c, stats = compress_model(cfg, params, cal,
                                     plan="*=slab@cr=0.5,iters=8")
    cr_meas = float(np.mean([s.cr for s in stats]))
    print(f"compressed {len(stats)} linears (measured CR={cr_meas:.3f}) "
          f"in {time.monotonic()-t0:.1f}s")

    # storage accounting on one layer's wq
    w = params["layers"]["attn"]["wq"][0].T.astype(jnp.float32)
    dec = slab_decompose(w, None, SLaBConfig(cr=0.5, iters=8))
    pk = packing.pack_decomposition(dec)
    dense_bytes = w.size * 2
    nnz = int(jnp.sum(dec.w_s != 0))
    packed_bytes = nnz * 2 + pk.b_packed.size * 4 + (pk.u.size + pk.v.size) * 2
    print(f"weight stream: dense {dense_bytes}B -> SLaB-packed "
          f"{packed_bytes}B ({dense_bytes/packed_bytes:.2f}x less HBM "
          f"traffic per decode step)")

    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    b, s_in, s_out = 8, 32, 16
    prompts = jnp.asarray(corpus.batch(0, b, s_in)["inputs"])
    t0 = time.monotonic()
    gen = greedy_decode(cfg, params_c, prompts, s_out)
    dt = time.monotonic() - t0
    print(f"served batch={b}: {(s_in+s_out)*b/dt:.1f} tok/s "
          f"(CPU, uncompiled-cache timing)")

    # quality spot check: compressed model still prefers corpus structure
    logits, _ = lm.forward(cfg.with_(dtype=jnp.float32),
                           jax.tree.map(lambda x: x.astype(jnp.float32),
                                        params_c),
                           prompts)
    acc = float(jnp.mean(jnp.argmax(logits[:, :-1], -1) ==
                         prompts[:, 1:]))
    print(f"next-token agreement on prompts: {100*acc:.1f}%")


if __name__ == "__main__":
    main()
