"""Data pipeline, optimizer, checkpoint, fault-tolerance tests."""
import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # property tests skip without hypothesis
    from conftest import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import SyntheticCorpus, calibration_batch, host_shard
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule, global_norm_clip,
                         int8_compress, int8_decompress)
from repro.optim.compress import ef_compress_pytree, ef_decompress_pytree
from repro.runtime.fault import FaultConfig, Supervisor


# ------------------------------- data ----------------------------------

def test_batches_deterministic_in_step():
    c = SyntheticCorpus(512, seed=3)
    b1 = c.batch(17, 8, 64)
    b2 = c.batch(17, 8, 64)
    np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
    b3 = c.batch(18, 8, 64)
    assert not np.array_equal(b1["inputs"], b3["inputs"])


def test_labels_are_shifted_inputs():
    b = SyntheticCorpus(512, seed=0).batch(0, 4, 32)
    np.testing.assert_array_equal(b["inputs"][:, 1:], b["labels"][:, :-1])


def test_corpus_is_learnable_structure():
    """Next token is predictable from current far above chance."""
    b = SyntheticCorpus(128, seed=0).batch(0, 64, 256)
    x, y = b["inputs"].ravel(), b["labels"].ravel()
    # conditional mode accuracy of P(y|x):
    from collections import Counter, defaultdict
    cond = defaultdict(Counter)
    for xi, yi in zip(x[:8000], y[:8000]):
        cond[xi][yi] += 1
    hits = sum(c.most_common(1)[0][1] for c in cond.values())
    tot = sum(sum(c.values()) for c in cond.values())
    assert hits / tot > 5.0 / 128       # >> uniform chance


def test_host_shard_partitions():
    b = SyntheticCorpus(64, seed=0).batch(0, 8, 16)
    parts = [host_shard(b, h, 4) for h in range(4)]
    cat = np.concatenate([p["inputs"] for p in parts])
    np.testing.assert_array_equal(cat, b["inputs"])


def test_calibration_protocol_shape():
    cal = calibration_batch(1000, n_seq=128, seq_len=2048)
    assert cal.shape == (128, 2048)
    assert cal.dtype == np.int32
    assert cal.max() < 1000


# ------------------------------ optim ----------------------------------

def test_adamw_decreases_quadratic():
    acfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                       total_steps=100)
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = adamw_init(params, acfg)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(g, opt, params, acfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


def test_cosine_schedule_shape():
    acfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                       min_lr_frac=0.1)
    lrs = [float(cosine_schedule(acfg, jnp.asarray(s)))
           for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 0.5) < 1e-6
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-6


def test_grad_clip():
    g = {"a": jnp.ones((100,)) * 10.0}
    clipped, gn = global_norm_clip(g, 1.0)
    assert abs(float(gn) - 100.0) < 1e-3
    norm_after = float(jnp.linalg.norm(clipped["a"]))
    assert abs(norm_after - 1.0) < 1e-4


def test_bf16_moments_option():
    acfg = AdamWConfig(moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = adamw_init(params, acfg)
    assert opt.mu["w"].dtype == jnp.bfloat16
    p2, o2, _ = adamw_update({"w": jnp.ones((4,))}, opt, params, acfg)
    assert o2.mu["w"].dtype == jnp.bfloat16


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 100), scale=st.floats(1e-6, 1e3))
def test_int8_roundtrip_bounded_error(seed, scale):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * scale
    q, s = int8_compress(g)
    back = int8_decompress(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9


def test_error_feedback_reduces_bias():
    """Sum of EF-compressed grads over steps converges to the true sum."""
    rng = jax.random.PRNGKey(0)
    err = {"w": jnp.zeros((64,))}
    true_sum = jnp.zeros((64,))
    ef_sum = jnp.zeros((64,))
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.fold_in(rng, i), (64,))}
        q, s, err = ef_compress_pytree(g, err)
        back = ef_decompress_pytree(q, s)
        true_sum = true_sum + g["w"]
        ef_sum = ef_sum + back["w"]
    resid = float(jnp.max(jnp.abs(true_sum - ef_sum - err["w"])))
    assert resid < 1e-3      # EF invariant: sum + carried error == truth


# ---------------------------- checkpoint -------------------------------

def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_save_load_roundtrip(tmp_path):
    t = _tree()
    save_pytree(t, str(tmp_path / "ck"))
    out = load_pytree(t, str(tmp_path / "ck"))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_atomic_commit_no_partial_state(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    mgr.save(1, _tree())
    mgr.save(2, _tree())
    mgr.save(3, _tree())
    assert mgr.steps() == [2, 3]          # keep=2 GC'd step 1
    assert mgr.latest_step() == 3
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_async_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert mgr.latest_step() == 5
    out = mgr.restore(_tree())
    np.testing.assert_array_equal(np.asarray(out["a"]),
                                  np.asarray(_tree()["a"]))


# -------------------------- fault tolerance ----------------------------

def test_supervisor_restores_and_replays(tmp_path):
    """Inject a failure mid-run; training must restore from the last
    commit and reach the same final state as an uninterrupted run."""
    def run(fail_at):
        mgr = CheckpointManager(str(tmp_path / f"f{fail_at}"),
                                async_write=False)
        state = {"x": jnp.zeros(())}
        failed = {"done": False}

        def step_fn(state, step):
            # deterministic "training": x += step
            return {"x": state["x"] + step}, {"loss": float(state["x"])}

        def fail_hook(step):
            if fail_at is not None and step == fail_at and not failed["done"]:
                failed["done"] = True
                return True
            return False

        sup = Supervisor(mgr, FaultConfig(ckpt_every=4, max_restarts=2),
                         failure_hook=fail_hook)
        out = sup.run(state, 0, 10, step_fn,
                      restore_fn=lambda s: mgr.restore({"x": jnp.zeros(())}))
        return float(out["x"]), sup.stats.restarts

    clean, r0 = run(None)
    faulty, r1 = run(6)
    assert r0 == 0 and r1 == 1
    assert clean == faulty == float(sum(range(10)))


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, {"x": jnp.zeros(())})
    sup = Supervisor(mgr, FaultConfig(ckpt_every=100, max_restarts=1),
                     failure_hook=lambda s: True)   # always failing
    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, 0, 5,
                lambda st, s: (st, {}),
                restore_fn=lambda s: mgr.restore({"x": jnp.zeros(())}))


def test_straggler_detection():
    mgr = None

    class NoopMgr:
        def wait(self):
            pass
        def save(self, *a):
            pass

    sup = Supervisor(NoopMgr(), FaultConfig(ckpt_every=1000,
                                            straggler_factor=3.0))
    slow = {8}

    def step_fn(state, step):
        time.sleep(0.05 if step in slow else 0.002)
        return state, {}

    sup.run({}, 0, 12, step_fn, restore_fn=lambda s: {})
    assert sup.stats.stragglers >= 1
