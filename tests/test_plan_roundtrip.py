"""Property-style round-trips for the plan DSL: parse → JSON → parse
and parse → repr → parse equality across rule precedence, layer
ranges, and @auto allocator options. The deterministic sweep always
runs; the hypothesis versions exercise random compositions when
hypothesis is installed and skip cleanly under the conftest stubs."""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # property tests skip without hypothesis
    from conftest import given, settings, strategies as st

from repro.core.plan import CompressionPlan, _AUTO_KEYS
from repro.core.slab import SLaBConfig

SPECS = [
    "*=slab",
    "attn.*=sparsegpt; *=slab@cr=0.4,pattern=2:4",
    "0-3/mlp.*=wanda@pattern=2:4; *=slab",
    "mamba.out=skip; 2/attn.*=wanda; 5-/mlp.*=magnitude; "
    "*=slab@group=[4,1]",
    "-2/attn.wq=sola@softness=0.25; *=hassle@rank=2,alt_iters=1",
    "0,2,7-/moe.shared.*=slab@cr=0.6; [am]*.out=skip; *=wanda",
    "budget=0.5; *=slab@auto",
    "budget=0.6; floor=0.1; ceiling=0.9; granularity=layer; "
    "attn.*=skip; *=wanda@auto",
    "candidates=[0.25,0.5,0.75]; 1-/mlp.*=slab@auto,iters=3; "
    "*=sparsegpt@cr=0.5; budget=0.4",
]

PROBES = [(0, "attn.wq"), (1, "attn.wo"), (2, "mlp.w_up"),
          (5, "mlp.w_down"), (3, "moe.shared.w_gate"), (7, "mamba.out")]


def _resolution(plan):
    """(method, scfg) per probe point, with @auto rules probed at the
    base config (the resolution that must survive a round-trip)."""
    out = []
    for layer, path in PROBES:
        r = plan.resolve(layer, path, allow_auto=True)
        out.append(None if r is None else (r.method, r.scfg))
    return out


@pytest.mark.parametrize("spec", SPECS)
def test_parse_json_parse_equality(spec):
    plan = CompressionPlan.parse(spec)
    again = CompressionPlan.parse(plan.to_json())
    assert again == plan
    assert _resolution(again) == _resolution(plan)


@pytest.mark.parametrize("spec", SPECS)
def test_parse_repr_parse_equality(spec):
    plan = CompressionPlan.parse(spec)
    again = CompressionPlan.parse(repr(plan))
    assert again == plan
    assert _resolution(again) == _resolution(plan)


@pytest.mark.parametrize("spec", SPECS)
def test_parse_dsl_parse_equality(spec):
    plan = CompressionPlan.parse(spec)
    again = CompressionPlan.parse(plan.to_dsl())
    assert again == plan


@pytest.mark.parametrize("spec", SPECS)
def test_roundtrip_preserves_base_and_auto_options(spec):
    base = SLaBConfig(cr=0.35, iters=3, group=(4, 1))
    plan = CompressionPlan.parse(spec, base=base)
    again = CompressionPlan.parse(plan.to_json())
    assert again.base == base
    assert again.auto_options == plan.auto_options
    assert again.is_auto == plan.is_auto


def test_int_and_list_layers_normalize_and_roundtrip():
    """Python-constructed rules with int / int-list layers compare
    equal to their DSL round-trip (layers normalize to the DSL string
    form at construction)."""
    from repro.core.plan import PlanRule
    plan = CompressionPlan([PlanRule("attn.*", "slab", layers=5),
                            PlanRule("mlp.*", "wanda", layers=[0, 2]),
                            PlanRule("*", "slab")])
    assert plan.rules[0].layers == "5"
    assert plan.rules[1].layers == "0,2"
    assert CompressionPlan.parse(plan.to_dsl()) == plan
    assert CompressionPlan.parse(plan.to_json()) == plan
    assert CompressionPlan.parse(repr(plan)) == plan
    assert plan.resolve(5, "attn.wq").method == "slab"
    assert plan.resolve(2, "mlp.w_up").method == "wanda"
    assert plan.resolve(1, "mlp.w_up").method == "slab"


def test_auto_flag_survives_all_routes():
    plan = CompressionPlan.parse("budget=0.5; *=slab@auto,iters=2")
    for route in (plan.to_dsl(), plan.to_json(), repr(plan)):
        p = CompressionPlan.parse(route)
        assert p.is_auto
        assert p.auto_options == {"budget": 0.5}
        assert p.rules[0].options == {"auto": True, "iters": 2}


def test_double_roundtrip_is_stable():
    """to_dsl is a fixed point after one parse (idempotent printing)."""
    for spec in SPECS:
        plan = CompressionPlan.parse(spec)
        once = plan.to_dsl()
        assert CompressionPlan.parse(once).to_dsl() == once
        jonce = plan.to_json()
        assert CompressionPlan.parse(jonce).to_json() == jonce


@settings(max_examples=60, deadline=None)
@given(spec=st.sampled_from(SPECS), budget=st.floats(0.05, 0.95),
       swap=st.booleans())
def test_property_composed_specs_roundtrip(spec, budget, swap):
    """Random compositions: any base spec, extra allocator segments,
    optional rule-order swap — every composition must round-trip
    through both JSON and repr."""
    composed = f"budget={budget}; {spec}"
    plan = CompressionPlan.parse(composed)
    if swap and len(plan.rules) > 1:
        plan = CompressionPlan(list(reversed(plan.rules)), plan.base,
                               plan.auto_options)
    assert CompressionPlan.parse(plan.to_json()) == plan
    assert CompressionPlan.parse(repr(plan)) == plan


@settings(max_examples=60, deadline=None)
@given(key=st.sampled_from(sorted(_AUTO_KEYS)),
       layers=st.sampled_from([None, "2", "0-3", "5-", "-2", "0,2,4"]),
       method=st.sampled_from(["slab", "wanda", "skip", "sparsegpt"]),
       auto=st.booleans())
def test_property_single_rule_roundtrip(key, layers, method, auto):
    val = {"budget": 0.5, "floor": 0.1, "ceiling": 0.9,
           "candidates": [0.2, 0.8], "granularity": "layer"}[key]
    opts = "@auto" if auto and method != "skip" else ""
    pre = f"{layers}/" if layers else ""
    import json
    spec = (f"{key}={json.dumps(val) if not isinstance(val, str) else val}"
            f"; {pre}*={method}{opts}")
    plan = CompressionPlan.parse(spec)
    assert plan.auto_options == {key: val}
    assert CompressionPlan.parse(plan.to_dsl()) == plan
    assert CompressionPlan.parse(plan.to_json()) == plan
    assert CompressionPlan.parse(repr(plan)) == plan
