"""Activation-tap subsystem: the single source of truth for calibration
statistics. Tapped ‖X‖₂ / X^T X must match independently hand-wired
references, MoE per-expert taps must see exactly the dispatched-token
subsets, and SparseGPT must now run end-to-end on every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import scores
from repro.core.pipeline import compress_model, layer_tap_stats, linear_paths
from repro.core.slab import SLaBConfig
from repro.data import calibration_batch
from repro.models import lm
from repro.models import moe as moe_lib
from repro.models.common import (positions_for, rms_norm, rotate,
                                 tap_capture, tap_scope)


def _ref_attention_context(cfg, ap, hn, positions):
    """Independent (non-chunked, einsum) attention up to the wo input."""
    b, s, _ = hn.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv, cfg.d_head
    q = (hn @ ap["wq"]).reshape(b, s, h, dh)
    k = (hn @ ap["wk"]).reshape(b, s, kv, dh)
    v = (hn @ ap["wv"]).reshape(b, s, kv, dh)
    q = rotate(cfg, q, positions)
    k = rotate(cfg, k, positions)
    g = h // kv
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
    q = q * (dh ** -0.5)
    logits = jnp.einsum("bqhd,bshd->bhqs", q, k,
                        preferred_element_type=jnp.float32)
    ii = jnp.arange(s)
    logits = jnp.where((ii[:, None] >= ii[None, :])[None, None],
                       logits, -1e30)
    probs = jax.nn.softmax(logits, -1).astype(cfg.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", probs, v).reshape(b, s, cfg.d_q)


def test_dense_tap_norms_match_handwired_reference():
    """Tapped norms for every dense-family linear — including attn.wo,
    whose stats used to be 'approximate' — equal a hand-wired rewiring
    of the layer to tight tolerance; tapped Hessians equal X^T X."""
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=32)
    h = lm.embed_inputs(cfg, params, jnp.asarray(cal))
    positions = positions_for(cfg, h.shape[0], h.shape[1])
    lp = jax.tree.map(lambda a: a[0], params["layers"])

    acts, hess = layer_tap_stats(cfg, params, lp, 0, h, positions,
                                 hessian=True)
    assert set(acts) == set(linear_paths(cfg))

    hn = rms_norm(h, lp["attn_norm"], cfg.norm_eps)
    ref = {p: scores.act_col_norms(hn)
           for p in ("attn.wq", "attn.wk", "attn.wv")}
    ctx = _ref_attention_context(cfg, lp["attn"], hn, positions)
    ref["attn.wo"] = scores.act_col_norms(ctx)
    h2 = h + ctx @ lp["attn"]["wo"]
    hm = rms_norm(h2, lp["mlp_norm"], cfg.norm_eps)
    ref["mlp.w_gate"] = scores.act_col_norms(hm)
    ref["mlp.w_up"] = scores.act_col_norms(hm)
    mid = jax.nn.silu(hm @ lp["mlp"]["w_gate"]) * (hm @ lp["mlp"]["w_up"])
    ref["mlp.w_down"] = scores.act_col_norms(mid)

    for pth, want in ref.items():
        np.testing.assert_allclose(np.asarray(acts[pth]), np.asarray(want),
                                   rtol=2e-5, atol=1e-5, err_msg=pth)

    flat = hn.reshape(-1, hn.shape[-1]).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(hess["attn.wq"]),
                               np.asarray(flat.T @ flat),
                               rtol=2e-5, atol=1e-4)
    fm = mid.reshape(-1, mid.shape[-1]).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(hess["mlp.w_down"]),
                               np.asarray(fm.T @ fm),
                               rtol=2e-5, atol=1e-4)


def test_moe_expert_taps_see_only_dispatched_tokens():
    """Per-expert tap stats equal the column norms of exactly the token
    subset routed to that expert — an engineered router makes the
    routing decision known in closed form."""
    e_cnt = 4
    cfg = configs.get("deepseek_moe_16b", smoke=True).with_(
        dtype=jnp.float32, n_experts=e_cnt, top_k=1, shared_ff=0,
        capacity_factor=float(e_cnt))   # capacity >= tokens: no drops
    key = jax.random.PRNGKey(3)
    d = cfg.d_model
    x = jax.random.normal(key, (1, 48, d), jnp.float32)
    p, _ = moe_lib.init_moe(cfg, jax.random.PRNGKey(4))
    # router: logit_e = 100 * x[..., e] -> expert = argmax of first E feats
    router = jnp.zeros((d, e_cnt), jnp.float32)
    router = router.at[jnp.arange(e_cnt), jnp.arange(e_cnt)].set(100.0)
    p["router"] = router

    with tap_capture(hessian=True) as tap:
        moe_lib.moe_ffn(cfg, p, x)

    xs = np.asarray(x).reshape(-1, d)
    owner = np.argmax(xs[:, :e_cnt], axis=-1)
    got = np.asarray(tap.norms("w_gate"))            # (E, D)
    assert got.shape == (e_cnt, d)
    for e in range(e_cnt):
        sub = xs[owner == e]
        want = np.sqrt((sub ** 2).sum(0)) if len(sub) else np.zeros(d)
        np.testing.assert_allclose(got[e], want, rtol=1e-5, atol=1e-5,
                                   err_msg=f"expert {e}")
        hz = np.asarray(tap.hessian("w_gate"))[e]
        np.testing.assert_allclose(hz, sub.T @ sub if len(sub)
                                   else np.zeros((d, d)),
                                   rtol=1e-4, atol=1e-4)
    # w_down taps live in the expert hidden space, per expert
    assert np.asarray(tap.norms("w_down")).shape == (e_cnt, cfg.d_ff)
    # per-expert token counts exclude padded capacity slots
    counts = np.asarray(tap.token_count("w_gate"))
    np.testing.assert_array_equal(
        counts, np.bincount(owner, minlength=e_cnt))


def test_hybrid_shared_block_taps_are_scoped():
    """On a shared-attention layer of the hybrid family, taps record the
    shared transformer block under 'shared.*' and the Mamba block under
    'mamba.*' — distinct names, no collisions."""
    cfg = configs.get("zamba2_7b", smoke=True).with_(dtype=jnp.float32)
    assert cfg.attn_every > 0
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=1, seq_len=16)
    h = lm.embed_inputs(cfg, params, jnp.asarray(cal))
    positions = positions_for(cfg, h.shape[0], h.shape[1])
    idx = cfg.attn_every - 1                    # shared block fires here
    lp = jax.tree.map(lambda a: a[idx], params["layers"])
    with tap_capture() as tap:
        lm._layer_fwd(cfg, params, lp, jnp.asarray(idx), h, positions)
    names = set(tap.names())
    assert {"mamba.in_z", "mamba.in_x", "mamba.out"} <= names
    assert {"shared.attn.wq", "shared.attn.wo", "shared.mlp.w_down"} <= names
    # non-shared layer: no shared.* taps
    lp0 = jax.tree.map(lambda a: a[0], params["layers"])
    with tap_capture() as tap0:
        lm._layer_fwd(cfg, params, lp0, jnp.asarray(0), h, positions)
    assert not any(n.startswith("shared.") for n in tap0.names())


@pytest.mark.parametrize("family_arch", ["deepseek_moe_16b", "mamba2_1_3b"])
def test_sparsegpt_end_to_end_on_nondense_families(family_arch):
    """SparseGPT used to be dense-only (no Hessian wiring for MoE/SSM);
    tapped per-family Hessians make it run everywhere."""
    cfg = configs.get(family_arch, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=32)
    new, stats = compress_model(cfg, params, cal, method="sparsegpt",
                                scfg=SLaBConfig(cr=0.5))
    assert len(stats) == cfg.n_layers * len(linear_paths(cfg))
    assert all(s.err_before > 0 for s in stats)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))
    # weights actually pruned: substantially zeroed, and the survivors
    # differ from the originals (SparseGPT's OBS error propagation)
    for pth in linear_paths(cfg):
        w_old = params["layers"]
        w_new = new["layers"]
        for k in pth.split("."):
            w_old, w_new = w_old[k], w_new[k]
        assert float(jnp.mean(w_new == 0)) > 0.2, pth
        assert not bool(jnp.all(w_new == w_old)), pth


def test_tap_capture_requires_eager_forward():
    """A tap hit inside traced code must fail loudly, not silently
    record garbage."""
    from repro.core.packed_model import linear
    w = jnp.ones((8, 4), jnp.float32)
    x = jnp.ones((3, 8), jnp.float32)
    with tap_capture():
        with pytest.raises(RuntimeError, match="traced"):
            jax.jit(lambda a: linear(a, w, tap="wq"))(x)


def test_taps_are_noop_without_capture():
    """Tagged linears outside a capture record nothing and tap scopes
    add nothing."""
    from repro.core.packed_model import linear
    w = jnp.ones((8, 4), jnp.float32)
    x = jnp.ones((3, 8), jnp.float32)
    with tap_scope("attn"):
        y = jax.jit(lambda a: linear(a, w, tap="wq"))(x)   # jit-safe
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w))
