"""Tests for the §Perf features: block remat, int8 KV cache, SP
attention fallback, and the HLO analysis that drives the roofline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.models.common import positions_for


def test_block_remat_matches_per_layer():
    """blocks:K checkpointing is a memory schedule, not a numerics
    change: loss and grads must match per-layer remat exactly."""
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32,
                                                     n_layers=4)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"inputs": t, "labels": jnp.roll(t, -1, 1)}
    pol = jax.checkpoint_policies.nothing_saveable
    l1, g1 = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch, pol, 1)[0])(params)
    l2, g2 = jax.value_and_grad(
        lambda p: lm.loss_fn(cfg, p, batch, pol, 2)[0])(params)
    assert float(l1) == float(l2)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_block_remat_odd_layers_falls_back():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32,
                                                     n_layers=3)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    # 3 % 2 != 0 -> per-layer path; must still run
    logits, _ = lm.forward(cfg, params, t, remat_block=2,
                           remat_policy=jax.checkpoint_policies.nothing_saveable)
    assert logits.shape == (1, 16, cfg.vocab)


def test_int8_kv_cache_decode_close_and_half_size():
    cfg = configs.get("stablelm_12b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    t = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab)
    full, _ = lm.forward(cfg, params, t)

    cfg_q = cfg.with_(kv_quant=True)
    cache = lm.init_cache(cfg_q, b, s)
    # payload is int8 at the same shape
    assert cache.kv.k.dtype == jnp.int8
    dec = jax.jit(lambda c, tok, p: lm.decode_step(cfg_q, params, c, tok, p))
    outs = []
    for i in range(s):
        pos = positions_for(cfg_q, b, 1, offset=i)
        lg, cache = dec(cache, t[:, i:i + 1], pos)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(got - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 0.06, rel          # int8 quantization budget


def test_sp_attention_numerics_unchanged():
    """sp_mode only adds sharding hints; on a 1-device mesh with an
    indivisible head count the result must equal the no-mesh result."""
    from repro.runtime.meshctx import use_mesh
    cfg = configs.get("llama3_2_3b", smoke=True).with_(dtype=jnp.float32)
    assert cfg.n_heads % 4 != 0 or True
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    base, _ = lm.forward(cfg, params, t)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with use_mesh(mesh):
        inmesh, _ = jax.jit(lambda p, x: lm.forward(cfg, p, x))(params, t)
    np.testing.assert_allclose(np.asarray(base), np.asarray(inmesh),
                               rtol=1e-5, atol=1e-5)


# ----------------------------- hlo_stats --------------------------------

def test_hlo_flops_match_analytic():
    from repro.launch import hlo_stats
    L, D, F, B = 3, 16, 32, 8

    def f(w1, w2, x):
        def body(h, ws):
            a, b = ws
            return jnp.tanh(h @ a @ b), ()
        h, _ = jax.lax.scan(body, x, (w1, w2))
        return jnp.sum(h)

    args = (jnp.zeros((L, D, F)), jnp.zeros((L, F, D)), jnp.zeros((B, D)))
    txt = jax.jit(jax.grad(f, argnums=(0, 1))).lower(*args).compile().as_text()
    st = hlo_stats.analyze(txt)
    # fwd 2 matmuls + bwd dgrad 2 + wgrad 2 => 3x fwd flops
    expect = 3 * L * (2 * B * D * F * 2)
    assert abs(st["hlo_flops"] - expect) / expect < 0.05, \
        (st["hlo_flops"], expect)


def test_hlo_trip_count_scaling():
    from repro.launch import hlo_stats

    def f(x):
        def body(h, _):
            return jnp.tanh(h @ h), None
        h, _ = jax.lax.scan(body, x, None, length=7)
        return h

    txt = jax.jit(f).lower(jnp.zeros((16, 16))).compile().as_text()
    st = hlo_stats.analyze(txt)
    expect = 7 * 2 * 16 * 16 * 16
    assert abs(st["hlo_flops"] - expect) / expect < 0.01


def test_hlo_collective_census():
    import os
    from repro.launch import hlo_stats
    if jax.device_count() < 2:
        pytest.skip("needs >1 device (run via tests/test_distributed.py)")


def test_collective_parser_on_text():
    from repro.launch import hlo_stats
    fake = """
HloModule m

ENTRY %main (p: f32[64,64]) -> f32[64,64] {
  %p = f32[64,64]{1,0} parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%p), replica_groups=[2,4]<=[8], dimensions={0}
  ROOT %ar = f32[64,64]{1,0} all-reduce(%ag), replica_groups=[1,8]<=[8], to_apply=%add
}
"""
    st = hlo_stats.collective_stats(fake)
    ag = st["per_type"]["all-gather"]
    ar = st["per_type"]["all-reduce"]
    assert ag["count"] == 1 and ar["count"] == 1
    out_b = 64 * 64 * 4
    assert ag["operand_bytes"] == out_b / 4          # group size 4
    assert ar["wire_bytes"] == 2 * out_b * 7 / 8     # ring, group 8
