"""Layer-wise compression pipeline: end-to-end on small trained-ish
models; the paper's protocol invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.pipeline import compress_model, linear_paths
from repro.core.slab import SLaBConfig
from repro.data import SyntheticCorpus, calibration_batch
from repro.models import lm
from repro.models.common import softmax_xent


def _eval_ppl(cfg, params, corpus, n=4, b=8, s=64):
    tot = 0.0
    for batch in corpus.eval_batches(n, b, s):
        logits, _ = lm.forward(cfg, params, jnp.asarray(batch["inputs"]))
        tot += float(softmax_xent(logits, jnp.asarray(batch["labels"])))
    return float(np.exp(tot / n))


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_compress_excludes_embed_and_head(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    new, stats = compress_model(cfg, params, cal,
                                plan="*=slab@cr=0.5,iters=2")
    np.testing.assert_array_equal(np.asarray(new["embed"]),
                                  np.asarray(params["embed"]))
    np.testing.assert_array_equal(np.asarray(new["lm_head"]),
                                  np.asarray(params["lm_head"]))
    # norms untouched
    np.testing.assert_array_equal(
        np.asarray(new["layers"]["attn_norm"]),
        np.asarray(params["layers"]["attn_norm"]))


def test_compress_touches_every_linear(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    new, stats = compress_model(cfg, params, cal,
                                plan="*=slab@cr=0.5,iters=2")
    n_expected = cfg.n_layers * len(linear_paths(cfg))
    assert len(stats) == n_expected
    assert all(s.method == "slab" for s in stats)
    for pth in ("attn", "mlp"):
        for name, w in new["layers"][pth].items():
            assert not np.array_equal(np.asarray(w),
                                      np.asarray(params["layers"][pth][name])), \
                f"{pth}.{name} unchanged"


@pytest.mark.parametrize("family_arch", ["mamba2_1_3b", "deepseek_moe_16b",
                                         "zamba2_7b"])
def test_compress_other_families(family_arch):
    cfg = configs.get(family_arch, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=32)
    new, stats = compress_model(cfg, params, cal, method="slab",
                                scfg=SLaBConfig(cr=0.5, iters=1))
    assert len(stats) > 0
    if cfg.family == "hybrid":
        # the shared transformer block is no longer silently skipped
        assert any(s.name.startswith("shared.") for s in stats)
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.slow
def test_slab_degrades_less_than_magnitude_on_trained_model():
    """Train a tiny LM for real, then compare compression damage: the
    paper's headline result at miniature scale. SLaB(50%) must lose less
    ppl than magnitude(50%) and stay close to dense."""
    from repro.launch.train import train
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    state, losses = train("llama2_7b", smoke=True, steps=120, batch=16,
                          seq=64, ckpt_dir=None, lr=3e-3, log_every=1000)
    params = state["params"]
    params = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    corpus = SyntheticCorpus(cfg.vocab, seed=0)
    ppl_dense = _eval_ppl(cfg, params, corpus)

    cal = calibration_batch(cfg.vocab, n_seq=8, seq_len=64)
    ppls = {}
    for method in ("slab", "magnitude"):
        new, _ = compress_model(cfg, params, cal, method=method,
                                scfg=SLaBConfig(cr=0.5, iters=5))
        ppls[method] = _eval_ppl(cfg, new, corpus)
    assert ppls["slab"] < ppls["magnitude"], (ppl_dense, ppls)
    assert ppls["slab"] < ppl_dense * 2.0, (ppl_dense, ppls)
