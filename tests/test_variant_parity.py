"""Cross-variant packed-serving parity sweep: every PackedLinear
variant × rank ∈ {1, r} × pattern ∈ {2:4, 4:8} against the pure-jnp
oracles in kernels/ref.py, so a kernel or packer edit can't silently
break a (variant, rank, pattern) combination the targeted tests don't
hit. Each case checks three-way agreement: the fused kernel (interpret
mode), the ref oracle fed the PACKED arrays, and the dense-applied
decomposition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.apply import slab_linear
from repro.core.packed_model import (PACKED_VARIANTS, pack_linear,
                                     packed_matmul, variant_of)
from repro.core.slab import SLaBDecomposition
from repro.core.sparsity import prune_mask
from repro.kernels import ref

N, K = 64, 128          # K divisible by 32 (sign bits), 4 and 8 (N:M)
_HAS_LOWRANK = ("slab-nm", "slab-ell", "slab-dense", "binlr",
                "lowrank-nm", "lowrank-ell", "lowrank-dense", "lowrank")


def _dec(seed, variant, rank, pattern):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(ks[0], (N, K), jnp.float32) * 0.1
    if variant in ("binlr", "lowrank"):
        w_s = jnp.zeros((N, K), jnp.float32)
    elif variant.endswith("-nm"):
        w_s = jnp.where(prune_mask(jnp.abs(w), 0.4, pattern=pattern),
                        w, 0.0)
    else:
        # unstructured: keep 0.4 routes to ELL (bytes win); keep 0.75
        # exceeds the K_max < 2/3·K f32 threshold and stays dense
        keep = 0.4 if variant.endswith("-ell") else 0.75
        w_s = jnp.where(prune_mask(jnp.abs(w), keep), w, 0.0)
    if rank:
        u = jax.random.normal(ks[1], (N, rank), jnp.float32) * 0.2
        v = jax.random.normal(ks[2], (K, rank), jnp.float32) * 0.2
    else:
        u = jnp.zeros((N, 0), jnp.float32)
        v = jnp.zeros((K, 0), jnp.float32)
    if variant.startswith("slab-") or variant == "binlr":
        w_b = jnp.where(jax.random.bernoulli(ks[3], 0.5, (N, K)),
                        1, -1).astype(jnp.int8)
    else:
        w_b = jnp.zeros((0, 0), jnp.int8)
    return SLaBDecomposition(w_s, u, v, w_b)


def _ref_oracle(x, pl):
    """kernels/ref.py oracle for one packed linear, from the packed
    arrays themselves (exercises unpack_nm / unpack_sign_bits too)."""
    if pl.variant == "slab-nm":
        return ref.slab_nm_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                      pl.m_pat, pl.b_packed, pl.u, pl.v)
    if pl.variant == "slab-ell":
        return ref.slab_ell_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                       pl.d_in, pl.b_packed, pl.u, pl.v)
    if pl.variant == "slab-dense":
        return ref.slab_matmul_ref(x, pl.sparse_vals, pl.b_packed,
                                   pl.u, pl.v)
    if pl.variant == "binlr":
        return ref.binlr_ref(x, pl.b_packed, pl.u, pl.v)
    if pl.variant == "lowrank-nm":
        return ref.slab_nm_lr_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                         pl.m_pat, pl.u, pl.v)
    if pl.variant == "lowrank-ell":
        return ref.ell_lr_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                     pl.d_in, pl.u, pl.v)
    if pl.variant == "lowrank-dense":
        return ref.slab_lr_matmul_ref(x, pl.sparse_vals, pl.u, pl.v)
    if pl.variant == "lowrank":
        return ref.lowrank_ref(x, pl.u, pl.v)
    if pl.variant == "sparse-nm":
        return ref.nm_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                 pl.m_pat)
    if pl.variant == "sparse-ell":
        return ref.ell_matmul_ref(x, pl.sparse_vals, pl.sparse_idx,
                                  pl.d_in)
    assert pl.variant == "sparse-dense"
    return x.astype(jnp.float32) @ pl.sparse_vals.astype(jnp.float32).T


def _cases():
    out = []
    for variant in PACKED_VARIANTS:
        ranks = (1, 3) if variant in _HAS_LOWRANK else (0,)
        patterns = (("2:4", "4:8") if variant.endswith("-nm")
                    else (None,))
        for rank in ranks:
            for pattern in patterns:
                out.append(pytest.param(
                    variant, rank, pattern,
                    id=f"{variant}-r{rank}-{pattern or 'unstructured'}"))
    return out


@pytest.mark.parametrize("variant,rank,pattern", _cases())
def test_packed_matches_ref_and_dense_apply(variant, rank, pattern):
    dec = _dec(7, variant, rank, pattern)
    assert variant_of(dec, pattern) == variant
    pl = pack_linear(dec, pattern)
    assert pl.variant == variant and pl.rank == rank
    x = jax.random.normal(jax.random.PRNGKey(8), (8, K), jnp.float32)
    got = packed_matmul(x, pl, interpret=True)
    want_ref = _ref_oracle(x, pl)
    want_dense = slab_linear(x, dec)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(want_ref),
                               np.asarray(want_dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("variant,rank,pattern", _cases())
def test_stacked_slice_preserves_variant(variant, rank, pattern):
    """Two stacked layers of one variant slice back to per-layer
    PackedLinears with identical aux metadata and numerics — the
    invariant the scanned serving path relies on."""
    pls = [pack_linear(_dec(s, variant, rank, pattern), pattern)
           for s in (11, 12)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *pls)
    assert stacked.variant == variant
    x = jax.random.normal(jax.random.PRNGKey(9), (4, K), jnp.float32)
    for i, pl in enumerate(pls):
        sl = jax.tree.map(lambda a: a[i], stacked)
        np.testing.assert_allclose(
            np.asarray(packed_matmul(x, sl, interpret=True)),
            np.asarray(packed_matmul(x, pl, interpret=True)),
            rtol=1e-5, atol=1e-5)
