"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single-CPU device; only tests/test_dryrun.py (subprocess) and the
sharding tests (their own 8-device subprocess config) use fake devices.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def make_weight(key, d_out, d_in, scale=0.05):
    return jax.random.normal(key, (d_out, d_in), jnp.float32) * scale


def make_acts(key, n, d_in):
    return jax.random.normal(key, (n, d_in), jnp.float32)
