"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single-CPU device; only tests/test_dryrun.py (subprocess) and the
sharding tests (their own 8-device subprocess config) use fake devices.

Also provides importorskip-style stand-ins for ``hypothesis`` (``given``
/ ``settings`` / ``strategies``) so property-based tests collect and
skip cleanly on machines without it, instead of erroring at collection.
"""
import os
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def settings(**kwargs):
    """No-op @settings stand-in (hypothesis not installed)."""
    return lambda f: f


def given(*args, **kwargs):
    """@given stand-in: the test collects but skips."""
    def deco(f):
        def skipper():        # no params: hypothesis args aren't fixtures
            pytest.skip("hypothesis not installed")
        skipper.__name__ = f.__name__
        skipper.__doc__ = f.__doc__
        return skipper
    return deco


def _any_strategy(*args, **kwargs):
    return None


strategies = types.SimpleNamespace(
    sampled_from=_any_strategy, floats=_any_strategy,
    integers=_any_strategy, booleans=_any_strategy, lists=_any_strategy)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def make_weight(key, d_out, d_in, scale=0.05):
    return jax.random.normal(key, (d_out, d_in), jnp.float32) * scale


def make_acts(key, n, d_in):
    return jax.random.normal(key, (n, d_in), jnp.float32)
