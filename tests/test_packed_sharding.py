"""Tensor-parallel specs for packed serving + segmented-scan fast paths.

The spec tests use a duck-typed mesh (``Planner`` only reads
``axis_names``/``shape`` to compute PartitionSpecs), so the fast tier
needs no fake devices. The decode parity test at the bottom needs a
real >= 2 device runtime and skips on one device —
``scripts/tier1.sh distributed`` runs this file under 2 fake CPU
devices.
"""
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.packed_model import (LR_SHARD_RANK, PACKED_VARIANTS,
                                     PackedStack, layer_slice_range,
                                     merge_packed_axes, pack_linear,
                                     packed_axes, packed_linear_axes)
from repro.core.slab import SLaBDecomposition
from repro.core.sparsity import prune_mask
from repro.models import lm
from repro.models.common import positions_for
from repro.runtime.sharding import Planner

from benchmarks.common import synthetic_pruned_packed

N, K = 64, 128
_HAS_LOWRANK = ("slab-nm", "slab-ell", "slab-dense", "binlr",
                "lowrank-nm", "lowrank-ell", "lowrank-dense", "lowrank")


class FakeMesh(NamedTuple):
    """Duck-typed stand-in: Planner.spec only reads these two fields."""
    axis_names: tuple
    shape: dict


MESH24 = FakeMesh(("data", "model"), {"data": 2, "model": 4})


def _dec(seed, variant, rank, pattern="2:4"):
    """One synthetic decomposition that classifies as ``variant``
    (mirrors the construction of the cross-variant parity sweep)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(ks[0], (N, K), jnp.float32) * 0.1
    if variant in ("binlr", "lowrank"):
        w_s = jnp.zeros((N, K), jnp.float32)
    elif variant.endswith("-nm"):
        w_s = jnp.where(prune_mask(jnp.abs(w), 0.4, pattern=pattern),
                        w, 0.0)
    else:
        keep = 0.4 if variant.endswith("-ell") else 0.75
        w_s = jnp.where(prune_mask(jnp.abs(w), keep), w, 0.0)
    if rank:
        u = jax.random.normal(ks[1], (N, rank), jnp.float32) * 0.2
        v = jax.random.normal(ks[2], (K, rank), jnp.float32) * 0.2
    else:
        u = jnp.zeros((N, 0), jnp.float32)
        v = jnp.zeros((K, 0), jnp.float32)
    if variant.startswith("slab-") or variant == "binlr":
        w_b = jnp.where(jax.random.bernoulli(ks[3], 0.5, (N, K)),
                        1, -1).astype(jnp.int8)
    else:
        w_b = jnp.zeros((0, 0), jnp.int8)
    return SLaBDecomposition(w_s, u, v, w_b)


def _pl(variant, rank=None):
    if rank is None:
        rank = 4 if variant in _HAS_LOWRANK else 0
    pattern = "2:4" if variant.endswith("-nm") else None
    pl = pack_linear(_dec(0, variant, rank, pattern or "2:4"), pattern)
    assert pl.variant == variant, (pl.variant, variant)
    return pl


# ---------------------------------------------------------------------------
# per-variant logical-axes trees
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", PACKED_VARIANTS)
def test_axes_tree_every_variant(variant):
    """Every stored plane except v leads with packed_out; aux matches
    the array leaf exactly so tree_map pairs the two structurally."""
    pl = _pl(variant)
    ax = packed_linear_axes(pl)
    for name in ("sparse_vals", "sparse_idx", "b_packed", "u"):
        arr, a = getattr(pl, name), getattr(ax, name)
        assert (arr is None) == (a is None), name
        if arr is not None:
            assert len(a) == arr.ndim, (name, a, arr.shape)
            if name != "u":
                assert a[0] == "packed_out", (name, a)
    if pl.v is not None:
        assert ax.v[0] is None           # contracts replicated features
    assert (ax.variant, ax.d_in, ax.d_out, ax.rank) == (
        pl.variant, pl.d_in, pl.d_out, pl.rank)
    # the stacked form prepends the never-sharded scan axis
    st = jax.tree.map(lambda a: a[None], pl)
    ax_st = packed_linear_axes(st, stacked=True)
    if pl.sparse_vals is not None:
        assert ax_st.sparse_vals[:2] == ("layers", "packed_out")


def test_u_shards_only_at_rank_threshold():
    lo, hi = _pl("lowrank", rank=LR_SHARD_RANK - 1), _pl(
        "lowrank", rank=LR_SHARD_RANK)
    assert packed_linear_axes(lo).u[0] is None
    assert packed_linear_axes(hi).u[0] == "packed_out"
    assert packed_linear_axes(hi).v[0] is None


# ---------------------------------------------------------------------------
# Planner specs (duck-typed mesh, no devices needed)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("variant", PACKED_VARIANTS)
def test_planner_spec_every_variant(variant):
    """tree_specs pairs the axes-PackedLinear against the array leaf and
    row-shards every d_out-leading plane on "model"."""
    cfg = configs.get("stablelm_12b", smoke=True)
    pl = _pl(variant, rank=LR_SHARD_RANK if variant in _HAS_LOWRANK
             else None)
    planner = Planner(MESH24, cfg)
    specs = planner.tree_specs(packed_axes(pl), pl)
    for name in ("sparse_vals", "sparse_idx", "b_packed", "u"):
        if getattr(pl, name) is not None:
            assert getattr(specs, name)[0] == "model", (name,
                                                        getattr(specs, name))
    if pl.v is not None:
        assert specs.v == P(None, None)


def test_packed_stack_specs_under_model_mesh():
    """A real heterogeneous model tree: stacked group planes get
    P(None, 'model', ...), the dense remainder P(None, None, 'model')."""
    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=4)
    _, packed, _ = synthetic_pruned_packed(
        cfg, lambda l: 0.25 if l < 2 else 0.5, skip={(0, "attn.wq")})
    planner = Planner(MESH24, cfg)
    specs = planner.tree_specs(
        merge_packed_axes(lm.param_axes(cfg), packed), packed)
    wq = specs["layers"]["attn"]["wq"]          # PackedStack of specs
    for g in wq.groups:
        assert g.sparse_vals == P(None, "model", None)
        assert g.sparse_idx == P(None, "model", None)
    assert wq.dense == P(None, None, "model")   # layer-0 dense remainder
    # dense (non-packed) leaves keep their usual rules
    assert specs["embed"] == P("model", "data")


def test_degraded_replication_spec():
    """d_out not divisible by the model axis -> every plane replicates
    (the planner's standard fallback), while divisible paths still
    shard."""
    cfg = configs.get("stablelm_12b", smoke=True).with_(d_ff=250)
    _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
    planner = Planner(MESH24, cfg)
    specs = planner.tree_specs(
        merge_packed_axes(lm.param_axes(cfg), packed), packed)
    def vals_specs(node):
        # a homogeneous whole-depth path packs to ONE stacked
        # PackedLinear; heterogeneous paths to a PackedStack of them
        groups = node.groups if isinstance(node, PackedStack) else (node,)
        return [g.sparse_vals for g in groups]

    for s in vals_specs(specs["layers"]["mlp"]["w_gate"]):
        assert s == P(None, None, None)               # 250 % 4 != 0
    for s in vals_specs(specs["layers"]["attn"]["wq"]):
        assert s == P(None, "model", None)


# ---------------------------------------------------------------------------
# segment pre-slicing (trivial-depth overhead shave)
# ---------------------------------------------------------------------------

def _hetero_stack(cfg):
    _, packed, _ = synthetic_pruned_packed(
        cfg, lambda l: 0.25 if l < 2 else 0.5, skip={(0, "attn.wq")})
    return packed["layers"]["attn"]["wq"]


def test_segment_returns_cached_identity():
    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=4)
    wq = _hetero_stack(cfg)
    assert isinstance(wq, PackedStack)
    a = wq.segment(2, 4)
    assert wq.segment(2, 4) is a               # memoized
    # a full-group run passes the stored stack through unsliced
    for gi, mem in enumerate(wq.members):
        lo, hi = min(mem), max(mem) + 1
        if tuple(range(lo, hi)) == mem:
            assert wq.segment(lo, hi) is wq.groups[gi]


def test_layer_slice_full_range_identity():
    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=4)
    _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
    layers = packed["layers"]
    assert layer_slice_range(layers, 0, cfg.n_layers) is not None
    sliced = layer_slice_range(layers, 0, cfg.n_layers)
    for a, b in zip(jax.tree.leaves(layers), jax.tree.leaves(sliced)):
        assert a is b                          # no copies at full range


def test_length_one_segments_skip_scan():
    """Per-layer segments at depth 2 run the body directly: the decode
    jaxpr contains no scan over the layer axis (trace counts stay one
    body per segment — test_segmented_scan pins that invariant)."""
    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=2)
    _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
    cache = lm.init_cache(cfg, 1, 2)
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = positions_for(cfg, 1, 1)
    jaxpr = jax.make_jaxpr(
        lambda c, t, p: lm.decode_step(cfg, packed, c, t, p,
                                       segments=((0, 1), (1, 2))))(
        cache, tok, pos)
    assert "scan" not in str(jaxpr.jaxpr)


# ---------------------------------------------------------------------------
# decode parity under a real mesh (scripts/tier1.sh distributed)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(jax.device_count() < 2,
                    reason="needs >= 2 devices (tier1.sh distributed)")
def test_mesh_decode_parity_two_devices():
    from repro.runtime.meshctx import use_mesh

    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=2)
    _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
    mesh = jax.make_mesh((1, 2), ("data", "model"))
    planner = Planner(mesh, cfg)
    placed = jax.device_put(packed, planner.tree_shardings(
        merge_packed_axes(lm.param_axes(cfg), packed), packed))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 3), 0, cfg.vocab)

    def dec(params, m):
        with use_mesh(m):
            cache = lm.init_cache(cfg, 2, 3)
            step = jax.jit(
                lambda c, t, p: lm.decode_step(cfg, params, c, t, p))
            for t in range(3):
                logits, cache = step(cache, toks[:, t:t + 1],
                                     positions_for(cfg, 2, 1, offset=t))
        return np.asarray(jax.device_get(logits))

    np.testing.assert_allclose(dec(placed, mesh), dec(packed, None),
                               rtol=2e-4, atol=2e-4)
