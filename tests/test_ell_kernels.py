"""Row-padded ELL gather-matmul kernels vs the pure-jnp oracles in
kernels/ref.py — shapes x sparsities x ranks, non-uniform row nnz
(realized K_max padding), grid tilings, and the bytes-win routing rule
that decides when unstructured decompositions leave the dense format."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import (ELLPacked, ell_pack, ell_row_nnz_max,
                                ell_unpack, ell_wins_bytes)
from repro.core.sparsity import prune_mask
from repro.kernels import ops, ref


def _sparse(seed, n, k, density, uniform=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    w = jax.random.normal(ks[0], (n, k), jnp.float32) * 0.1
    if uniform:
        return jnp.where(prune_mask(jnp.abs(w), density), w, 0.0)
    # Bernoulli mask: per-row nnz varies, exercising the K_max pad
    mask = jax.random.bernoulli(ks[1], density, (n, k))
    return jnp.where(mask, w, 0.0)


def _uv(seed, n, k, rank):
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    return (jax.random.normal(ks[0], (n, rank), jnp.float32) * 0.2,
            jax.random.normal(ks[1], (k, rank), jnp.float32) * 0.2)


def _bits(seed, n, k):
    from repro.core.packing import pack_sign_bits
    w_b = jnp.where(jax.random.bernoulli(jax.random.PRNGKey(seed),
                                         0.5, (n, k)), 1, -1)
    return pack_sign_bits(w_b.astype(jnp.int8))


# ------------------------------------------------------------------
# Packing format
# ------------------------------------------------------------------

def test_ell_pack_realized_kmax_roundtrip():
    """Default nnz=None pads to the realized per-row max and the
    unpack reproduces the matrix exactly — including short rows."""
    ws = _sparse(0, 48, 96, 0.4, uniform=False)
    p = ell_pack(ws)
    assert p.indices.dtype == jnp.uint16
    assert p.values.shape[1] == ell_row_nnz_max(ws)
    np.testing.assert_allclose(np.asarray(ell_unpack(p)), np.asarray(ws))


def test_ell_pack_wide_din_uses_uint32():
    """D_in past 65535 would wrap uint16 ids; the packer widens to
    uint32 and round-trips columns above the uint16 ceiling exactly."""
    d_in = 2 ** 16 + 64
    ws = jnp.zeros((2, d_in), jnp.float32)
    ws = ws.at[0, d_in - 1].set(1.5).at[1, 7].set(-2.0)
    p = ell_pack(ws)
    assert p.indices.dtype == jnp.uint32
    assert int(p.indices[0, 0]) == d_in - 1
    np.testing.assert_allclose(np.asarray(ell_unpack(p)), np.asarray(ws))


def test_variant_routing_follows_pack_itemsize():
    """The ELL-vs-dense race depends on the SERVING value width: a 50%
    unstructured layer wins at f32 (0.75x) but ties at bf16 — so a bf16
    pack must route it to sparse-dense, not sparse-ell."""
    from repro.core.slab import SLaBDecomposition
    from repro.core.packed_model import variant_of
    ws = _sparse(20, 32, 64, 0.5)
    dec = SLaBDecomposition(ws, jnp.zeros((32, 0)), jnp.zeros((64, 0)),
                            jnp.zeros((0, 0), jnp.int8))
    assert variant_of(dec, None, itemsize=4) == "sparse-ell"
    assert variant_of(dec, None, itemsize=2) == "sparse-dense"


def test_ell_wins_bytes_threshold():
    """f32 values + uint16 ids: ELL wins iff K_max < 2/3 D_in; bf16
    values tighten it to 1/2."""
    assert ell_wins_bytes(85, 128, itemsize=4)       # 85*6 < 128*4
    assert not ell_wins_bytes(86, 128, itemsize=4)   # 86*6 > 512
    assert ell_wins_bytes(63, 128, itemsize=2)
    assert not ell_wins_bytes(64, 128, itemsize=2)   # exact tie loses
    # Past the uint16 ceiling indices cost 4 bytes: win iff K_max < D_in/2.
    wide = 2 ** 16 + 32
    assert ell_wins_bytes(wide // 2 - 16, wide, itemsize=4)
    assert not ell_wins_bytes(wide // 2, wide, itemsize=4)


# ------------------------------------------------------------------
# Kernels vs refs vs dense oracle
# ------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(64, 128), (96, 160), (128, 64)])
@pytest.mark.parametrize("density", [0.2, 0.5])
@pytest.mark.parametrize("uniform", [True, False],
                         ids=["rows-uniform", "rows-ragged"])
def test_ell_matmul_matches_ref_and_dense(n, k, density, uniform):
    ws = _sparse(1, n, k, density, uniform)
    p = ell_pack(ws)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, k), jnp.float32)
    got = ops.ell_matmul(x, p.values, p.indices, interpret=True)
    want = ref.ell_matmul_ref(x, p.values, p.indices, k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(want), np.asarray(x @ ws.T),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rank", [1, 3])
def test_ell_lr_matmul_matches_ref(rank):
    ws = _sparse(3, 96, 128, 0.4, uniform=False)
    p = ell_pack(ws)
    u, v = _uv(4, 96, 128, rank)
    x = jax.random.normal(jax.random.PRNGKey(5), (8, 128), jnp.float32)
    got = ops.ell_lr_matmul(x, p.values, p.indices, u, v, interpret=True)
    want = ref.ell_lr_matmul_ref(x, p.values, p.indices, 128, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    dense = x @ ws.T + (x @ v) @ u.T
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rank", [1, 3])
def test_slab_ell_matmul_matches_ref(rank):
    ws = _sparse(6, 64, 160, 0.5, uniform=False)
    p = ell_pack(ws)
    u, v = _uv(7, 64, 160, rank)
    bp = _bits(8, 64, 160)
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 160), jnp.float32)
    got = ops.slab_ell_matmul(x, p.values, p.indices, bp, u, v,
                              interpret=True)
    want = ref.slab_ell_matmul_ref(x, p.values, p.indices, 160, bp, u, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_ell_matmul_fori_chunk_path():
    """A small jc forces the fori_loop chunking (the O(1)-trace path
    used at realistic K_max) plus the static remainder tail; numerics
    must match the single-chunk result exactly."""
    from repro.kernels import ell as ell_k
    ws = _sparse(16, 64, 128, 0.55, uniform=False)   # K_max ~ 70-ish
    p = ell_pack(ws)
    assert p.values.shape[1] // 4 > 4                # fori path engages
    x = jax.random.normal(jax.random.PRNGKey(17), (8, 128), jnp.float32)
    got = ell_k.ell_matmul(x, p.values, p.indices, jc=4, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ ws.T),
                               rtol=1e-4, atol=1e-4)


def test_ell_matmul_multi_tile_grid():
    """bn smaller than N and row padding (M not a block multiple)
    tile correctly."""
    ws = _sparse(10, 128, 96, 0.4)
    p = ell_pack(ws)
    x = jax.random.normal(jax.random.PRNGKey(11), (5, 96), jnp.float32)
    got = ops.ell_matmul(x, p.values, p.indices, bm=2, bn=32,
                         interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ ws.T),
                               rtol=1e-4, atol=1e-4)


def test_ell_matmul_leading_batch_dims():
    ws = _sparse(12, 64, 96, 0.3)
    p = ell_pack(ws)
    x = jax.random.normal(jax.random.PRNGKey(13), (2, 3, 96), jnp.float32)
    got = ops.ell_matmul(x, p.values, p.indices, interpret=True)
    assert got.shape == (2, 3, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ ws.T),
                               rtol=1e-4, atol=1e-4)


def test_ell_all_zero_row_serves_zeros():
    """A row with zero nnz pads to width ≥ 1 and contributes nothing."""
    ws = _sparse(14, 32, 64, 0.4).at[3].set(0.0)
    p = ell_pack(ws)
    x = jax.random.normal(jax.random.PRNGKey(15), (4, 64), jnp.float32)
    got = ops.ell_matmul(x, p.values, p.indices, interpret=True)
    np.testing.assert_allclose(np.asarray(got[:, 3]), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got), np.asarray(x @ ws.T),
                               rtol=1e-4, atol=1e-4)
