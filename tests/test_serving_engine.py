"""Continuous-batching serving engine: scheduler policy units, paged
decode-step parity, and end-to-end open-loop traces (dense and
SLaB-packed) checked token-exact against per-request greedy_decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.pipeline import compress_model
from repro.core.slab import SLaBConfig
from repro.core.packed_model import pack_model
from repro.data import calibration_batch
from repro.launch.serve import greedy_decode
from repro.models import lm
from repro.serving import (BlockAllocator, Engine, EngineConfig, Request,
                           Scheduler, init_paged_cache)
from repro.serving.paged_cache import blocks_needed, paged_write


# ----------------------------------------------------------------------
# Block allocator / paged-cache units
# ----------------------------------------------------------------------

def test_allocator_all_or_nothing():
    a = BlockAllocator(4)
    got = a.alloc(3)
    assert got is not None and len(got) == 3 and a.n_free == 1
    assert a.alloc(2) is None            # insufficient: nothing taken
    assert a.n_free == 1
    a.free(got)
    assert a.n_free == 4


def test_allocator_rejects_double_free():
    a = BlockAllocator(2)
    ids = a.alloc(1)
    a.free(ids)
    with pytest.raises(ValueError):
        a.free(ids)


def test_blocks_needed():
    assert blocks_needed(1, 16) == 1
    assert blocks_needed(16, 16) == 1
    assert blocks_needed(17, 16) == 2


def test_paged_write_masks_inactive_rows():
    pool = jnp.zeros((4, 2, 3, 8))       # (n_blocks, bs, KV, dh)
    new = jnp.ones((3, 8))               # one token's (KV, dh) per row
    out = paged_write(pool, jnp.stack([new, new * 5]),
                      block_ids=jnp.array([1, 2]),
                      offsets=jnp.array([0, 1]),
                      active=jnp.array([True, False]))
    assert float(jnp.sum(jnp.abs(out[2]))) == 0.0   # masked row dropped
    np.testing.assert_allclose(np.asarray(out[1, 0]), np.asarray(new))


def test_init_paged_cache_rejects_cacheless_families():
    cfg = configs.get("mamba2_1_3b", smoke=True)
    with pytest.raises(ValueError):
        init_paged_cache(cfg, 8, 16)


# ----------------------------------------------------------------------
# Scheduler policy units (no model involved)
# ----------------------------------------------------------------------

def _req(rid, p_len, max_new=4, arrival=0.0):
    return Request(rid=rid, prompt=np.full(p_len, rid + 1, np.int32),
                   max_new=max_new, arrival=arrival)


def test_scheduler_admits_in_arrival_order():
    s = Scheduler(n_slots=2, n_blocks=16, block_size=4, max_len=32)
    s.submit(_req(0, 4, arrival=5.0))
    s.submit(_req(1, 4, arrival=1.0))
    s.submit(_req(2, 4, arrival=3.0))
    assert s.admit(now=0.0) == []        # nothing has arrived
    s.admit(now=10.0)
    admitted = sorted(sl.req.rid for sl in s.slots.values())
    assert admitted == [1, 2]            # earliest arrivals fill slots
    assert [r.rid for r in s.waiting] == [0]


def test_scheduler_rejects_oversized_request():
    """Unservable requests reject-with-status instead of raising: one
    bad request must not kill the trace."""
    s = Scheduler(n_slots=1, n_blocks=4, block_size=4, max_len=16)
    r = _req(0, 14, max_new=8)               # 21 cached > max_len
    assert s.submit(r) is False
    assert r.status == "rejected" and "max_len" in r.error
    assert not s.pending and not s.waiting
    s2 = Scheduler(n_slots=1, n_blocks=2, block_size=4, max_len=32)
    r2 = _req(1, 12, max_new=8)
    assert s2.submit(r2) is False
    assert r2.status == "rejected" and "cannot ever run" in r2.error


def test_scheduler_retire_frees_blocks_and_slot():
    s = Scheduler(n_slots=1, n_blocks=8, block_size=4, max_len=32,
                  prefill_chunk=8)
    s.submit(_req(0, 6, max_new=1))
    s.admit(0.0)
    plan = s.plan_step()
    assert plan is not None
    tokens, n_valid, any_prefill = plan
    assert any_prefill and n_valid[0] == 6
    assert s.alloc.n_free < 8
    retired = s.commit_step(n_valid, np.array([42]), now=1.0)
    assert [r.rid for r in retired] == [0]   # max_new=1: done after prefill
    assert retired[0].out == [42] and retired[0].ttft == 1.0
    assert s.alloc.n_free == 8 and not s.slots


def test_scheduler_evicts_lifo_and_requeues():
    # pool of 4 blocks x 4 tokens; two 8-token prompts fit exactly,
    # first decode-growth OOMs and must evict the LATEST admit
    s = Scheduler(n_slots=2, n_blocks=4, block_size=4, max_len=16,
                  prefill_chunk=8)
    s.submit(_req(0, 8, max_new=4, arrival=0.0))
    s.submit(_req(1, 8, max_new=4, arrival=1.0))
    s.admit(2.0)
    tokens, n_valid, _ = s.plan_step()
    s.commit_step(n_valid, np.array([7, 9]), now=3.0)
    assert all(sl.phase == "decode" for sl in s.slots.values())
    plan = s.plan_step()                 # both rows want block 5 -> OOM
    assert plan is not None
    tokens, n_valid, any_prefill = plan
    assert s.n_evictions == 1
    victims = [r.rid for r in s.waiting]
    assert victims == [1]                # LIFO: later arrival evicted
    # the victim's already-emitted token is folded into its replay prompt
    assert list(s.waiting[0].serve_prompt()[-1:]) == [9]
    survivors = [sl.req.rid for sl in s.slots.values()]
    assert survivors == [0] and n_valid[list(s.slots)[0]] == 1


def test_scheduler_admission_watermark_blocks_thrash():
    """A waiting request whose prompt exceeds free blocks must NOT be
    admitted (it would instantly evict itself back)."""
    s = Scheduler(n_slots=2, n_blocks=4, block_size=4, max_len=16,
                  prefill_chunk=16)
    s.submit(_req(0, 12, max_new=2))
    s.submit(_req(1, 12, max_new=2))
    s.admit(0.0)
    tokens, n_valid, _ = s.plan_step()
    s.commit_step(n_valid, np.array([3, 3]), now=1.0)
    running = [sl.req.rid for sl in s.slots.values()]
    assert running == [0]                # second stayed in the queue
    assert [r.rid for r in s.waiting] == [1]


# ----------------------------------------------------------------------
# End-to-end: engine output == per-request greedy_decode
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def packed_setup():
    cfg = configs.get("stablelm_12b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    dense_c, stats, decs = compress_model(
        cfg, params, cal, method="slab",
        scfg=SLaBConfig(cr=0.5, iters=3, pattern="2:4"),
        keep_decompositions=True)
    packed = pack_model(dense_c, decs, cfg.n_layers, pattern="2:4")
    return cfg, packed


def _trace(cfg, specs, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=p, dtype=np.int64
                                        ).astype(np.int32),
                    max_new=n, arrival=a)
            for i, (p, n, a) in enumerate(specs)]


def _check_against_greedy(cfg, params, reqs):
    for r in reqs:
        want = np.asarray(greedy_decode(
            cfg, params, jnp.asarray(r.prompt)[None, :], r.max_new))[0]
        got = np.asarray(r.out, np.int32)
        assert np.array_equal(got, want), (
            f"rid={r.rid}: engine {got} != greedy {want}")


def test_engine_mixed_arrival_trace_matches_greedy(dense_setup):
    """≥3 requests, different prompt/output lengths, admitted at
    different steps, more requests than slots — token-exact vs the
    per-request static path."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(9, 6, 0.0), (17, 9, 2.0), (5, 12, 5.0),
                        (23, 4, 5.0)])
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=3, n_blocks=32, block_size=4,
                              max_len=64, prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=500)
    assert all(r.status == "finished" for r in done)
    assert all(r.n_generated == r.max_new for r in done)
    assert all(r.ttft is not None and r.finish is not None for r in done)
    # staggered arrivals really were admitted at different times
    assert len({r.ttft + r.arrival for r in done}) > 1
    _check_against_greedy(cfg, params, done)


def test_engine_eviction_replay_is_exact(dense_setup):
    """A pool too small for all streams forces evict -> requeue ->
    recompute; greedy determinism makes the replay token-exact."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(10, 8, 0.0), (12, 8, 0.0), (8, 8, 0.0)], seed=1)
    eng = Engine(cfg, params,
                 EngineConfig(n_slots=3, n_blocks=8, block_size=4,
                              max_len=32, prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=2000)
    assert eng.sched.n_evictions > 0     # the point of this pool size
    _check_against_greedy(cfg, params, done)


def test_engine_packed_slab_trace_matches_greedy(packed_setup):
    """The acceptance trace: mixed arrivals through a SLaB-packed
    (fused-kernel) model — engine tokens == per-request greedy_decode
    with the same packed params."""
    cfg, packed = packed_setup
    reqs = _trace(cfg, [(7, 5, 0.0), (13, 7, 3.0), (4, 9, 6.0)], seed=2)
    eng = Engine(cfg, packed,
                 EngineConfig(n_slots=2, n_blocks=24, block_size=4,
                              max_len=48, prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=1000)
    _check_against_greedy(cfg, packed, done)


def test_engine_int8_kv_trace(dense_setup):
    """kv_quant engine run: parity vs greedy_decode under the SAME
    quantized cache config."""
    cfg, params = dense_setup
    cfg8 = cfg.with_(kv_quant="int8")
    reqs = _trace(cfg8, [(8, 5, 0.0), (14, 6, 1.0), (6, 7, 2.0)], seed=3)
    eng = Engine(cfg8, params,
                 EngineConfig(n_slots=3, n_blocks=32, block_size=4,
                              max_len=64, prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=500)
    _check_against_greedy(cfg8, params, done)


def test_engine_rejects_cacheless_family():
    cfg = configs.get("mamba2_1_3b", smoke=True)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError):
        Engine(cfg, params, EngineConfig(n_slots=1, n_blocks=4,
                                         block_size=4, max_len=16))


def test_greedy_decode_ragged_lengths(dense_setup):
    """Right-padded batch + lengths array == per-row decode."""
    cfg, params = dense_setup
    rng = np.random.default_rng(4)
    lens = np.array([11, 5, 16, 8], np.int32)
    s, gen = int(lens.max()), 6
    prompts = np.zeros((len(lens), s), np.int32)
    rows = []
    for i, L in enumerate(lens):
        rows.append(rng.integers(0, cfg.vocab, size=int(L)
                                 ).astype(np.int32))
        prompts[i, :L] = rows[-1]
    got = np.asarray(greedy_decode(cfg, params, jnp.asarray(prompts),
                                   gen, lengths=lens))
    for i, p in enumerate(rows):
        want = np.asarray(greedy_decode(cfg, params,
                                        jnp.asarray(p)[None], gen))[0]
        assert np.array_equal(got[i], want), i
    # lengths == full width must agree with the dense two-scan path
    full = np.asarray(greedy_decode(cfg, params, jnp.asarray(prompts),
                                    gen))
    fullr = np.asarray(greedy_decode(
        cfg, params, jnp.asarray(prompts), gen,
        lengths=np.full(len(lens), s, np.int32)))
    assert np.array_equal(full, fullr)


def test_paged_decode_step_matches_dense_decode(dense_setup):
    """Model-level parity: paged_decode_step through a scattered block
    pool vs decode_step on a contiguous cache, 6 steps."""
    cfg, params = dense_setup
    b, n_blocks, bs = 3, 16, 4
    rng = np.random.default_rng(5)
    toks = rng.integers(0, cfg.vocab, size=(b, 6)).astype(np.int32)
    bt = np.zeros((b, 4), np.int32)
    perm = rng.permutation(n_blocks)[:b * 2].reshape(b, 2)
    bt[:, :2] = perm                      # scattered physical blocks
    paged = init_paged_cache(cfg, n_blocks, bs)
    cache = lm.init_cache(cfg, b, 8)
    lengths = jnp.zeros((b,), jnp.int32)
    active = jnp.ones((b,), bool)
    from repro.models.common import positions_for
    for t in range(6):
        tok = jnp.asarray(toks[:, t:t + 1])
        lp, paged = lm.paged_decode_step(cfg, params, paged,
                                         jnp.asarray(bt), lengths, tok,
                                         active)
        ld, cache = lm.decode_step(cfg, params, cache, tok,
                                   positions_for(cfg, b, 1, offset=t))
        lengths = lengths + 1
    rel = (float(jnp.max(jnp.abs(lp[:, 0] - ld[:, -1])))
           / float(jnp.max(jnp.abs(ld))))
    assert rel < 1e-4, rel
