"""Flash-decode Pallas kernel: shape/dtype/length sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode

CASES = [  # (B, KV, G, dh, S, bs)
    (2, 4, 3, 32, 256, 64),
    (1, 8, 4, 64, 512, 128),
    (4, 2, 12, 64, 128, 128),    # qwen2-vl-like grouping, single chunk
    (2, 1, 1, 128, 256, 64),     # MQA
]


def _mk(case, dtype=jnp.float32, seed=0):
    b, kv, g, dh, s, bs = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = (jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
         * dh ** -0.5).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=("f32", "bf16"))
def test_flash_decode_matches_ref(case, dtype):
    q, k, v, lengths = _mk(case, dtype)
    want = ref.flash_decode_ref(q, k, v, lengths)
    got = flash_decode(q, k, v, lengths, bs=case[-1], interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES[:2], ids=str)
def test_flash_decode_int8(case):
    q, k, v, lengths = _mk(case)

    def quant(t):
        sc = jnp.maximum(jnp.max(jnp.abs(t), -1) / 127.0, 1e-8)
        qv = jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
        return qv.astype(jnp.int8), sc

    kq, ks_ = quant(k)
    vq, vs_ = quant(v)
    want = ref.flash_decode_ref(q, kq, vq, lengths, ks_, vs_)
    got = flash_decode(q, kq, vq, lengths, ks_, vs_, bs=case[-1],
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # and the quantized result tracks the exact one within int8 budget
    exact = ref.flash_decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - exact))) < 0.05


def test_flash_decode_respects_length():
    """Tokens beyond `length` must not influence the output."""
    case = (1, 2, 2, 16, 128, 32)
    q, k, v, _ = _mk(case)
    lengths = jnp.array([64], jnp.int32)
    base = flash_decode(q, k, v, lengths, bs=32, interpret=True)
    k2 = k.at[:, 64:].set(999.0)        # poison the invalid region
    v2 = v.at[:, 64:].set(-999.0)
    poisoned = flash_decode(q, k2, v2, lengths, bs=32, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               atol=1e-6)
