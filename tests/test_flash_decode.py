"""Flash-decode Pallas kernel: shape/dtype/length sweeps vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode, flash_decode_paged

CASES = [  # (B, KV, G, dh, S, bs)
    (2, 4, 3, 32, 256, 64),
    (1, 8, 4, 64, 512, 128),
    (4, 2, 12, 64, 128, 128),    # qwen2-vl-like grouping, single chunk
    (2, 1, 1, 128, 256, 64),     # MQA
]


def _mk(case, dtype=jnp.float32, seed=0):
    b, kv, g, dh, s, bs = case
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = (jax.random.normal(ks[0], (b, kv, g, dh), jnp.float32)
         * dh ** -0.5).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kv, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kv, dh), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1).astype(jnp.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("case", CASES, ids=str)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=("f32", "bf16"))
def test_flash_decode_matches_ref(case, dtype):
    q, k, v, lengths = _mk(case, dtype)
    want = ref.flash_decode_ref(q, k, v, lengths)
    got = flash_decode(q, k, v, lengths, bs=case[-1], interpret=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("case", CASES[:2], ids=str)
def test_flash_decode_int8(case):
    q, k, v, lengths = _mk(case)

    def quant(t):
        sc = jnp.maximum(jnp.max(jnp.abs(t), -1) / 127.0, 1e-8)
        qv = jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
        return qv.astype(jnp.int8), sc

    kq, ks_ = quant(k)
    vq, vs_ = quant(v)
    want = ref.flash_decode_ref(q, kq, vq, lengths, ks_, vs_)
    got = flash_decode(q, kq, vq, lengths, ks_, vs_, bs=case[-1],
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    # and the quantized result tracks the exact one within int8 budget
    exact = ref.flash_decode_ref(q, k, v, lengths)
    assert float(jnp.max(jnp.abs(got - exact))) < 0.05


def _quant(t):
    sc = jnp.maximum(jnp.max(jnp.abs(t), -1) / 127.0, 1e-8)
    qv = jnp.clip(jnp.round(t / sc[..., None]), -127, 127)
    return qv.astype(jnp.int8), sc


@pytest.mark.parametrize("case", [
    (2, 2, 2, 32, 100, 32),      # s % bs != 0: final chunk padded
    (1, 4, 2, 16, 7, 32),        # bs > s: single clamped chunk
    (2, 1, 1, 16, 33, 32),       # one token past the chunk boundary
], ids=str)
def test_flash_decode_nondivisible(case):
    """s need not be a multiple of bs: the kernel pads the tail chunk
    and masks it with the valid-length predicate."""
    q, k, v, lengths = _mk(case)
    want = ref.flash_decode_ref(q, k, v, lengths)
    got = flash_decode(q, k, v, lengths, bs=case[-1], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_nondivisible_int8():
    case = (2, 2, 2, 32, 100, 32)
    q, k, v, lengths = _mk(case)
    kq, ks_ = _quant(k)
    vq, vs_ = _quant(v)
    want = ref.flash_decode_ref(q, kq, vq, lengths, ks_, vs_)
    got = flash_decode(q, kq, vq, lengths, ks_, vs_, bs=case[-1],
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_ragged_int8_parity():
    """int8 path at ragged per-row lengths (incl. length == 1)."""
    b, kv, g, dh, s, bs = 4, 2, 3, 32, 96, 32
    q, k, v, _ = _mk((b, kv, g, dh, s, bs), seed=3)
    lengths = jnp.array([1, 17, 96, 40], jnp.int32)
    kq, ks_ = _quant(k)
    vq, vs_ = _quant(v)
    want = ref.flash_decode_ref(q, kq, vq, lengths, ks_, vs_)
    got = flash_decode(q, kq, vq, lengths, ks_, vs_, bs=bs,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Paged variant: reads K/V through per-request block tables
# ----------------------------------------------------------------------

def _scatter_to_pool(k, v, bs_blk, n_blocks, seed=0):
    """Lay contiguous (B, S, KV, dh) K/V into a shuffled block pool;
    returns pools, block tables, and the inverse layout check data."""
    b, s, kv, dh = k.shape
    n_bt = -(-s // bs_blk)
    assert n_blocks >= b * n_bt
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n_blocks)[:b * n_bt].reshape(b, n_bt)
    kp = np.zeros((n_blocks, bs_blk, kv, dh), np.asarray(k).dtype)
    vp = np.zeros_like(kp)
    pad = n_bt * bs_blk - s
    kc = np.pad(np.asarray(k), ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = np.pad(np.asarray(v), ((0, 0), (0, pad), (0, 0), (0, 0)))
    for r in range(b):
        for j in range(n_bt):
            kp[perm[r, j]] = kc[r, j * bs_blk:(j + 1) * bs_blk]
            vp[perm[r, j]] = vc[r, j * bs_blk:(j + 1) * bs_blk]
    return (jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(perm, jnp.int32))


def test_flash_decode_paged_matches_ref():
    b, kv, g, dh, s = 3, 2, 2, 32, 60
    q, k, v, _ = _mk((b, kv, g, dh, s, 16), seed=5)
    lengths = jnp.array([60, 13, 1], jnp.int32)
    kp, vp, bt = _scatter_to_pool(k, v, bs_blk=16, n_blocks=16)
    want = ref.flash_decode_ref(q, k, v, lengths)
    got = flash_decode_paged(q, kp, vp, bt, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_paged_int8():
    b, kv, g, dh, s = 2, 2, 2, 32, 48
    q, k, v, _ = _mk((b, kv, g, dh, s, 16), seed=7)
    lengths = jnp.array([48, 29], jnp.int32)
    kq, ks_ = _quant(k)
    vq, vs_ = _quant(v)
    want = ref.flash_decode_ref(q, kq, vq, lengths, ks_, vs_)
    kp, vp, bt = _scatter_to_pool(kq, vq, bs_blk=16, n_blocks=8)
    ksp, vsp, _ = _scatter_to_pool(ks_[..., None], vs_[..., None],
                                   bs_blk=16, n_blocks=8)
    got = flash_decode_paged(q, kp, vp, bt, lengths,
                             ksp[..., 0], vsp[..., 0], interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_flash_decode_paged_zero_length_rows():
    """Inactive slots (length 0) must come back as exact zeros."""
    b, kv, g, dh, s = 2, 2, 2, 16, 32
    q, k, v, _ = _mk((b, kv, g, dh, s, 16), seed=9)
    lengths = jnp.array([32, 0], jnp.int32)
    kp, vp, bt = _scatter_to_pool(k, v, bs_blk=16, n_blocks=8)
    got = np.asarray(flash_decode_paged(q, kp, vp, bt, lengths,
                                        interpret=True))
    want = np.asarray(ref.flash_decode_ref(q, k, v, lengths))
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)
    assert np.array_equal(got[1], np.zeros_like(got[1]))


def test_flash_decode_respects_length():
    """Tokens beyond `length` must not influence the output."""
    case = (1, 2, 2, 16, 128, 32)
    q, k, v, _ = _mk(case)
    lengths = jnp.array([64], jnp.int32)
    base = flash_decode(q, k, v, lengths, bs=32, interpret=True)
    k2 = k.at[:, 64:].set(999.0)        # poison the invalid region
    v2 = v.at[:, 64:].set(-999.0)
    poisoned = flash_decode(q, k2, v2, lengths, bs=32, interpret=True)
    np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                               atol=1e-6)
