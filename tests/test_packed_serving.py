"""End-to-end packed serving: compress -> pack -> forward/decode through
the fused Pallas kernels (interpret mode on CPU; Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.packed_model import PackedLinear, pack_model, packed_matmul
from repro.core.pipeline import compress_model, linear_paths
from repro.core.slab import SLaBConfig
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for


@pytest.fixture(scope="module")
def packed_setup():
    cfg = configs.get("stablelm_12b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    dense_c, stats, decs = compress_model(
        cfg, params, cal, method="slab",
        scfg=SLaBConfig(cr=0.5, iters=3, pattern="2:4"),
        keep_decompositions=True)
    packed = pack_model(dense_c, decs, cfg.n_layers, pattern="2:4")
    return cfg, dense_c, packed, decs


def test_all_target_linears_packed(packed_setup):
    cfg, _, packed, decs = packed_setup
    leaves = jax.tree_util.tree_flatten_with_path(
        packed["layers"], is_leaf=lambda x: isinstance(x, PackedLinear))[0]
    n_packed = sum(isinstance(l, PackedLinear) for _, l in leaves)
    assert n_packed == len(linear_paths(cfg))
    assert len(decs) == cfg.n_layers * len(linear_paths(cfg))


def test_packed_forward_matches_dense_equivalent(packed_setup):
    cfg, dense_c, packed, _ = packed_setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    rel = float(jnp.max(jnp.abs(f_d - f_p))) / float(jnp.max(jnp.abs(f_d)))
    assert rel < 1e-4, rel


def test_packed_decode_matches_dense_equivalent(packed_setup):
    cfg, dense_c, packed, _ = packed_setup
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    cd = lm.init_cache(cfg, b, s)
    cp = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    rel = float(jnp.max(jnp.abs(ld - lp))) / float(jnp.max(jnp.abs(ld)))
    assert rel < 1e-4, rel


def test_packed_stack_slices_through_scan(packed_setup):
    """PackedLinear is a pure-array pytree: stacked layers slice in
    lax.scan like plain weights (what the model relies on)."""
    _, _, packed, _ = packed_setup
    wq = packed["layers"]["attn"]["wq"]
    assert isinstance(wq, PackedLinear)
    assert wq.variant == "slab-nm" and wq.rank == 1
    one = jax.tree.map(lambda x: x[0], wq)
    assert one.variant == wq.variant            # aux survives slicing
    x = jax.random.normal(jax.random.PRNGKey(3), (4, one.d_in))
    y = packed_matmul(x, one, interpret=True)
    assert y.shape == (4, one.d_out)


def test_unstructured_pack_mode():
    """Dense-masked W_S fallback (no N:M pattern)."""
    cfg = configs.get("stablelm_12b", smoke=True).with_(
        dtype=jnp.float32, n_layers=1)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    dense_c, _, decs = compress_model(
        cfg, params, cal, method="slab",
        scfg=SLaBConfig(cr=0.5, iters=2), keep_decompositions=True)
    packed = pack_model(dense_c, decs, cfg.n_layers, pattern=None)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    np.testing.assert_allclose(np.asarray(f_p), np.asarray(f_d),
                               rtol=1e-4, atol=1e-4)
