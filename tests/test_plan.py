"""Compressor registry + CompressionPlan: rule resolution, third-party
registration through the pipeline with zero pipeline edits, mixed-method
end-to-end runs, hybrid shared-block compression, streaming multi-batch
calibration, and measured-CR reporting."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compressor
from repro.core.compressor import CompressedLinear, LinearStats
from repro.core.pipeline import (compress_model, linear_paths,
                                 shared_linear_paths)
from repro.core.plan import (CalibrationSpec, CompressionPlan, PlanRule,
                             plan_for_method)
from repro.core.slab import SLaBConfig, compression_ratio
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for, tap_capture


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# ------------------------------------------------------------------
# Plan-rule resolution
# ------------------------------------------------------------------

def test_rule_precedence_glob_and_layer_ranges():
    plan = CompressionPlan.parse(
        "mamba.out=skip; 0-1/attn.*=sparsegpt; attn.wq=wanda; "
        "*=slab@cr=0.4,pattern=2:4")
    # first match wins: the layer-ranged sparsegpt rule shadows the
    # wanda rule inside layers 0..1
    assert plan.resolve(0, "attn.wq").method == "sparsegpt"
    assert plan.resolve(1, "attn.wk").method == "sparsegpt"
    # outside the range, the next matching rule applies
    assert plan.resolve(2, "attn.wq").method == "wanda"
    assert plan.resolve(2, "attn.wk").method == "slab"
    # per-rule config overrides the base
    r = plan.resolve(3, "mlp.w_up")
    assert r.method == "slab"
    assert r.scfg.cr == 0.4 and r.scfg.pattern == "2:4"
    # explicit skip
    assert plan.resolve(5, "mamba.out") is None
    # open-ended and single-layer specs
    plan2 = CompressionPlan.parse("3-/mlp.*=magnitude; 2/attn.*=wanda")
    assert plan2.resolve(7, "mlp.w_up").method == "magnitude"
    assert plan2.resolve(2, "mlp.w_up") is None       # out of range
    assert plan2.resolve(2, "attn.wo").method == "wanda"
    assert plan2.resolve(3, "attn.wo") is None
    # no catch-all: unmatched linears stay dense
    assert plan2.resolve(0, "moe.w_gate") is None


def test_json_and_inline_specs_resolve_identically():
    inline = CompressionPlan.parse(
        "0-3/attn.*=sparsegpt; moe.shared.*=slab@cr=0.4; *=slab")
    as_json = CompressionPlan.parse(json.dumps([
        {"match": "attn.*", "method": "sparsegpt", "layers": "0-3"},
        {"match": "moe.shared.*", "method": "slab", "cr": 0.4},
        {"match": "*", "method": "slab"},
    ]))
    for layer, path in [(0, "attn.wq"), (5, "attn.wq"),
                        (2, "moe.shared.w_gate"), (9, "mlp.w_down")]:
        a, b = inline.resolve(layer, path), as_json.resolve(layer, path)
        assert a.method == b.method, (layer, path)
        assert a.scfg == b.scfg, (layer, path)


def test_bare_rule_dict_and_empty_specs():
    """A single rule object (not wrapped in a list) is a valid spec; a
    spec that resolves to zero rules is a loud error, never a silent
    compress-nothing plan."""
    plan = CompressionPlan.parse({"match": "*", "method": "slab",
                                  "cr": 0.4})
    assert plan.resolve(0, "attn.wq").scfg.cr == 0.4
    with pytest.raises(ValueError, match="zero rules"):
        CompressionPlan.parse("")
    with pytest.raises(ValueError, match="zero rules"):
        CompressionPlan.parse({"rules": []})
    with pytest.raises(ValueError, match="zero rules"):
        CompressionPlan.parse([])


def test_inline_options_accept_json_literals_with_commas():
    plan = CompressionPlan.parse("*=wanda@group=[4,1],cr=0.6")
    r = plan.resolve(0, "mlp.w_up")
    assert r.scfg.group == (4, 1) and r.scfg.cr == 0.6
    # "/" in an option value is not a layer-range separator; a glob
    # starting with a character class is not JSON
    rule = CompressionPlan.parse("*=slab@pattern=2:4; [am]*.out=skip") \
        .rules[0]
    assert rule.layers is None and rule.options == {"pattern": "2:4"}
    plan2 = CompressionPlan.parse("[am]*.out=skip; *=slab")
    assert plan2.resolve(0, "mamba.out") is None
    assert plan2.resolve(0, "attn.wo").method == "slab"


def test_plan_needs_drive_hessian_collection():
    """The resolved compressor's ``needs`` decides which stats exist."""
    assert "hessian" in compressor.get("sparsegpt").needs
    assert "hessian" in compressor.get("hassle").needs
    assert "hessian" not in compressor.get("slab").needs
    assert compressor.get("magnitude").needs == frozenset()


def test_unknown_compressor_raises_with_available_list():
    with pytest.raises(KeyError, match="slab"):
        compressor.get("definitely-not-registered")
    plan = CompressionPlan.parse("*=definitely-not-registered")
    with pytest.raises(KeyError):
        plan.resolve(0, "attn.wq")


# ------------------------------------------------------------------
# Registry: third-party compressor, zero pipeline edits
# ------------------------------------------------------------------

def test_third_party_compressor_plugs_in_via_plan(small_model):
    """A compressor registered outside core.* is selected by a plan and
    applied by compress_model with no edits to core/pipeline.py."""

    @compressor.register("halve-test")
    class HalveCompressor(compressor.Compressor):
        needs = frozenset()

        def compress(self, w, stats):
            return CompressedLinear(0.5 * w, None, 0.25)

    try:
        cfg, params = small_model
        cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
        new, stats = compress_model(cfg, params, cal,
                                    plan="attn.wq=halve-test; *=skip")
        assert [s.name for s in stats] == ["attn.wq"] * cfg.n_layers
        assert all(s.method == "halve-test" and s.cr == 0.25
                   for s in stats)
        np.testing.assert_allclose(
            np.asarray(new["layers"]["attn"]["wq"]),
            0.5 * np.asarray(params["layers"]["attn"]["wq"]), rtol=1e-6)
        # everything else untouched
        np.testing.assert_array_equal(
            np.asarray(new["layers"]["mlp"]["w_up"]),
            np.asarray(params["layers"]["mlp"]["w_up"]))
    finally:
        compressor._REGISTRY.pop("halve-test", None)
    assert "halve-test" not in compressor.available()


# ------------------------------------------------------------------
# Mixed-method end-to-end
# ------------------------------------------------------------------

@pytest.mark.slow
def test_mixed_method_plan_end_to_end(small_model):
    """sparsegpt on attention + slab on the MLP in one run; Hessians are
    collected only for the attention linears."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    new, stats = compress_model(
        cfg, params, cal,
        plan="attn.*=sparsegpt; mlp.*=slab@iters=2")
    by_method = {s.name.split(".")[0] for s in stats
                 if s.method == "sparsegpt"}
    assert by_method == {"attn"}
    assert {s.name.split(".")[0] for s in stats if s.method == "slab"} \
        == {"mlp"}
    assert len(stats) == cfg.n_layers * len(linear_paths(cfg))
    # sparsegpt actually pruned the attention weights
    wq = np.asarray(new["layers"]["attn"]["wq"])
    assert float(np.mean(wq == 0)) > 0.2
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_hassle_beats_wanda_on_lowrank_plus_sparse_matrix():
    """The HASSLE-free alternating compressor recovers low-rank
    structure a pure pruner cannot, under the Hessian-weighted error
    both optimize."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(48, 2)) @ rng.normal(size=(2, 64))
                    + 0.3 * rng.normal(size=(48, 64)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(256, 64)), jnp.float32)
    hess = x.T @ x
    norms = jnp.sqrt(jnp.sum(x * x, axis=0))
    comp = compressor.get("hassle", SLaBConfig(cr=0.5, rank=2),
                          alt_iters=2)
    cl = comp.compress(w, LinearStats(norms=norms, hessian=hess))
    assert cl.dec is not None
    assert 0.4 < cl.cr < 0.6                  # near the requested budget
    # dense equivalent is exactly W_S + U Vᵀ
    np.testing.assert_allclose(
        np.asarray(cl.dense),
        np.asarray(cl.dec.w_s + cl.dec.u @ cl.dec.v.T),
        rtol=1e-4, atol=1e-5)
    lc = np.linalg.cholesky(np.asarray(hess, np.float64)
                            + 1e-6 * np.eye(64))
    from repro.core import baselines
    err_h = np.linalg.norm((np.asarray(w) - np.asarray(cl.dense)) @ lc)
    err_w = np.linalg.norm(
        (np.asarray(w) - np.asarray(baselines.wanda_prune(w, norms, 0.5)))
        @ lc)
    assert err_h < err_w, (err_h, err_w)


def test_hassle_runs_through_the_pipeline(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    new, stats = compress_model(
        cfg, params, cal, plan="attn.wo=hassle@alt_iters=1; *=skip")
    assert [s.method for s in stats] == ["hassle"] * cfg.n_layers
    wo = np.asarray(new["layers"]["attn"]["wo"])
    assert not np.array_equal(wo, np.asarray(params["layers"]["attn"]["wo"]))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


# ------------------------------------------------------------------
# Hybrid shared block
# ------------------------------------------------------------------

def test_hybrid_shared_block_is_compressed_once():
    """shared.* weights (outside the stacked layers) are addressed by
    the plan like any other linear — compressed at the first firing
    layer, exactly once, without touching the Mamba stack when the plan
    says so."""
    cfg = configs.get("zamba2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    new, stats = compress_model(cfg, params, cal,
                                plan="shared.*=slab@iters=1; *=skip")
    assert sorted(s.name for s in stats) == sorted(shared_linear_paths(cfg))
    assert all(s.layer == cfg.attn_every - 1 for s in stats)
    for mod in ("attn", "mlp"):
        for name, w_old in params["shared_attn"][mod].items():
            assert not np.array_equal(
                np.asarray(new["shared_attn"][mod][name]),
                np.asarray(w_old)), f"shared.{mod}.{name} unchanged"
    # the Mamba stack was skipped by the plan
    assert np.array_equal(np.asarray(new["layers"]["mamba"]["out"]),
                          np.asarray(params["layers"]["mamba"]["out"]))
    # caller's params were not mutated
    assert new["shared_attn"] is not params["shared_attn"]
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


# ------------------------------------------------------------------
# Streaming multi-batch calibration
# ------------------------------------------------------------------

def test_streaming_stats_match_single_batch(small_model):
    """One tap capture over N chunked forwards accumulates the same
    norms and Hessians as one forward over the full batch."""
    cfg, params = small_model
    cal = np.asarray(calibration_batch(cfg.vocab, n_seq=4, seq_len=32))
    lp = jax.tree.map(lambda a: a[0], params["layers"])

    def stats_for(chunks):
        with tap_capture(hessian=True,
                         hessian_names={"attn.wq", "mlp.w_down"}) as tap:
            for c in chunks:
                h = lm.embed_inputs(cfg, params, jnp.asarray(c))
                pos = positions_for(cfg, h.shape[0], h.shape[1])
                lm._layer_fwd(cfg, params, lp, jnp.asarray(0), h, pos)
        return tap

    one = stats_for([cal])
    many = stats_for(CalibrationSpec(cal, batch_size=1).batches())
    for name in ("attn.wq", "mlp.w_down"):
        np.testing.assert_allclose(np.asarray(many.norms(name)),
                                   np.asarray(one.norms(name)),
                                   rtol=1e-5, atol=1e-6, err_msg=name)
        np.testing.assert_allclose(np.asarray(many.hessian(name)),
                                   np.asarray(one.hessian(name)),
                                   rtol=1e-5, atol=1e-4, err_msg=name)
    assert many.token_count("attn.wq") == one.token_count("attn.wq")


def test_streaming_compression_matches_single_batch(small_model):
    """compress_model under a chunked CalibrationSpec reproduces the
    single-batch result on identical data (error propagation runs
    per-chunk through the same compressed prefix)."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    one, _ = compress_model(cfg, params, cal, plan="*=slab@iters=2")
    many, _ = compress_model(cfg, params,
                             CalibrationSpec(cal, batch_size=2),
                             plan="*=slab@iters=2")
    for (pa, la), (pb, lb) in zip(
            jax.tree_util.tree_flatten_with_path(one["layers"])[0],
            jax.tree_util.tree_flatten_with_path(many["layers"])[0]):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-4, atol=1e-5,
                                   err_msg=jax.tree_util.keystr(pa))


# ------------------------------------------------------------------
# Measured compression ratio
# ------------------------------------------------------------------

def test_stats_record_measured_cr(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    new, stats, decs = compress_model(
        cfg, params, cal, plan="*=slab@iters=2",
        keep_decompositions=True)
    for s in stats:
        want = compression_ratio(decs[(s.layer, s.name)])
        assert abs(s.cr - want) < 1e-9, (s.name, s.cr, want)
    # pruning-only methods report the achieved zero fraction
    new2, stats2 = compress_model(cfg, params, cal,
                                  plan="attn.wq=wanda@cr=0.3; *=skip")
    for s in stats2:
        w = np.asarray(new2["layers"]["attn"]["wq"][s.layer])
        assert abs(s.cr - float(np.mean(w == 0))) < 1e-9


def test_method_sugar_equals_catch_all_plan(small_model):
    """compress_model(method=...) is exactly plan_for_method(...)."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    scfg = SLaBConfig(cr=0.5, iters=1)
    a, _ = compress_model(cfg, params, cal, method="wanda", scfg=scfg)
    b, _ = compress_model(cfg, params, cal,
                          plan=plan_for_method("wanda", scfg))
    for la, lb in zip(jax.tree.leaves(a["layers"]),
                      jax.tree.leaves(b["layers"])):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_plan_rule_dataclass_roundtrip():
    rule = PlanRule("moe.shared.*", "slab", layers="0-3",
                    options={"cr": 0.4})
    plan = CompressionPlan.parse([rule])
    r = plan.resolve(2, "moe.shared.w_up")
    assert r.method == "slab" and r.scfg.cr == 0.4
    assert plan.resolve(4, "moe.shared.w_up") is None
