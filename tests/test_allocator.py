"""Sensitivity-driven CR allocator: water-filling solver on
hand-checkable frontiers, budget feasibility, floor/ceiling clamps,
shared-block grouping, probe exactness for score-based pruners, the
one-calibration-pass guarantee, and the acceptance property (allocated
summed err_after <= uniform at equal measured global CR)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import allocator
from repro.core.allocator import (Frontier, allocate_plan,
                                  measured_global_cr, waterfill)
from repro.core.baselines import wanda_prune
from repro.core.pipeline import (collect_model_stats, compress_model,
                                 shared_linear_paths)
from repro.core.plan import CompressionPlan
from repro.core.scores import weighted_fro_error
from repro.data import calibration_batch
from repro.models import lm


@pytest.fixture(scope="module")
def small_model():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _global_cr(cfg, params, rows):
    return measured_global_cr(params, rows)


# ------------------------------------------------------------------
# Water-filling solver (deterministic hand-checkable fixtures)
# ------------------------------------------------------------------

def _fr(key, size, crs, errs):
    return Frontier(key, size, np.asarray(crs, float),
                    np.asarray(errs, float))


GRID = [0.2, 0.4, 0.6, 0.8]


def test_waterfill_hand_checked_three_layer_fixture():
    """Equal sizes, budget 0.6 = six 0.2-steps above the floor. The six
    cheapest marginal steps are c,c,c (0.5 each), a,a (1 each), b (5):
    a->0.6, b->0.4, c->0.8; mean exactly 0.6."""
    fronts = [_fr("a", 100, GRID, [0, 1, 2, 10]),
              _fr("b", 100, GRID, [0, 5, 10, 20]),
              _fr("c", 100, GRID, [0, 0.5, 1.0, 1.5])]
    got = waterfill(fronts, budget=0.6)
    assert got == {"a": 0.6, "b": 0.4, "c": 0.8}


def test_waterfill_sensitive_layer_protected():
    """A layer whose error explodes keeps the lowest CR; the
    insensitive layers absorb the budget."""
    fronts = [_fr("sensitive", 10, GRID, [0, 100, 200, 300]),
              _fr("easy1", 10, GRID, [0, 0.1, 0.2, 0.3]),
              _fr("easy2", 10, GRID, [0, 0.1, 0.2, 0.3])]
    got = waterfill(fronts, budget=0.6)
    assert got["sensitive"] == 0.2
    assert got["easy1"] == 0.8 and got["easy2"] == 0.8


def test_waterfill_budget_below_floor_sum_is_trivially_met():
    fronts = [_fr("a", 1, GRID, [0, 1, 2, 3])]
    assert waterfill(fronts, budget=0.1) == {"a": 0.2}


def test_waterfill_infeasible_budget_raises():
    fronts = [_fr("a", 1, GRID, [0, 1, 2, 3]),
              _fr("b", 1, GRID, [0, 1, 2, 3])]
    with pytest.raises(ValueError, match="infeasible"):
        waterfill(fronts, budget=0.9)
    with pytest.raises(ValueError, match="infeasible"):
        waterfill(fronts, budget=0.7, ceiling=0.6)


def test_waterfill_floor_ceiling_clamps():
    fronts = [_fr("a", 1, GRID, [0, 1, 2, 10]),
              _fr("b", 1, GRID, [0, 5, 10, 20])]
    got = waterfill(fronts, budget=0.5, floor=0.4, ceiling=0.6)
    assert set(got.values()) <= {0.4, 0.6}
    assert sum(got.values()) / 2 >= 0.5
    with pytest.raises(ValueError, match="no admissible"):
        waterfill(fronts, budget=0.5, floor=0.85)


def test_waterfill_size_weighting():
    """Budget is weighted by parameter count: a huge cheap group meets
    the budget almost alone."""
    fronts = [_fr("big", 9000, GRID, [0, 0.1, 0.2, 0.3]),
              _fr("tiny", 1000, GRID, [0, 50, 100, 200])]
    got = waterfill(fronts, budget=0.6)
    assert got["big"] == 0.8 and got["tiny"] == 0.2
    # 0.9*0.8 + 0.1*0.2 = 0.74 >= 0.6 but no single step less
    fronts2 = [_fr("big", 9000, GRID, [0, 0.1, 0.2, 0.3]),
               _fr("tiny", 1000, GRID, [0, 50, 100, 200])]
    assert waterfill(fronts2, budget=0.56)["big"] == 0.6


def test_waterfill_never_worse_than_uniform():
    """Predicted error of the solution is <= the uniform-at-budget
    allocation whenever that allocation is on the grid."""
    rng = np.random.default_rng(3)
    for _ in range(20):
        fronts = [_fr(f"g{i}", int(rng.integers(1, 100)) * 10, GRID,
                      np.cumsum(rng.gamma(1.0, 5.0, size=len(GRID))))
                  for i in range(4)]
        got = waterfill(fronts, budget=0.6)
        pred = uni = 0.0
        for f in fronts:
            pred += float(f.errs[list(f.crs).index(got[f.key])])
            uni += float(f.errs[list(f.crs).index(0.6)])
        assert pred <= uni + 1e-12


# ------------------------------------------------------------------
# Sensitivity probe
# ------------------------------------------------------------------

def test_probe_curve_matches_actual_wanda_error():
    """For score-based pruners the frontier is EXACT: the predicted
    err_after equals the measured activation-weighted error of the
    pruned matrix at every candidate CR."""
    from repro.core import compressor as compressor_lib
    rng = np.random.default_rng(0)
    w_model = jnp.asarray(rng.normal(size=(96, 64)), jnp.float32)  # (D_in, D_out)
    norms = jnp.asarray(np.abs(rng.normal(size=(96,))) + 0.1, jnp.float32)
    comp = compressor_lib.get("wanda")
    curve, err_b = allocator._leaf_curve(w_model, norms, comp,
                                         [0.3, 0.5, 0.7])
    w_paper = w_model.T
    assert err_b == pytest.approx(
        float(weighted_fro_error(w_paper, jnp.zeros_like(w_paper), norms)),
        rel=1e-5)
    for cr, pred in curve.items():
        pruned = wanda_prune(w_paper, norms, 1.0 - cr)
        want = float(weighted_fro_error(w_paper, pruned, norms))
        assert pred == pytest.approx(want, rel=1e-5, abs=1e-6), cr


def test_probe_respects_method_budget_model():
    """slab's keep fraction pays for the binary + low-rank terms, so at
    the same CR its probe prunes more mass than wanda's."""
    from repro.core import compressor as compressor_lib
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    n = jnp.ones((64,), jnp.float32)
    cw, _ = allocator._leaf_curve(w, n, compressor_lib.get("wanda"), [0.5])
    cs, _ = allocator._leaf_curve(w, n, compressor_lib.get("slab"), [0.5])
    assert cs[0.5] > cw[0.5]
    # infeasible candidates are absent instead of raising
    chigh, _ = allocator._leaf_curve(
        w, n, compressor_lib.get("slab"), [0.5, 0.99])
    assert 0.99 not in chigh and 0.5 in chigh


# ------------------------------------------------------------------
# allocate_plan end-to-end
# ------------------------------------------------------------------

def test_allocated_beats_uniform_at_equal_cr(small_model):
    """THE acceptance property: from one shared set of tapped stats,
    the water-filled plan's summed err_after is <= the uniform plan's
    at equal (±1%) measured global CR."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=4, seq_len=32)
    stats = collect_model_stats(cfg, params, cal, plan="*=wanda")
    _, urows = compress_model(cfg, params, None, plan="*=wanda@cr=0.6",
                              stats=stats)
    alloc = allocate_plan(cfg, params, budget=0.6, template="*=wanda",
                          stats=stats)
    _, arows = compress_model(cfg, params, None, plan=alloc.plan,
                              stats=alloc.stats)
    err_u = sum(s.err_after for s in urows)
    err_a = sum(s.err_after for s in arows)
    assert err_a <= err_u * (1 + 1e-6), (err_a, err_u)
    assert abs(_global_cr(cfg, params, arows)
               - _global_cr(cfg, params, urows)) <= 0.01
    # the probe is exact for wanda: predicted == measured
    assert alloc.predicted_err == pytest.approx(err_a, rel=1e-4)
    # and the allocation is non-trivial (actually reallocates)
    assert len(set(alloc.crs.values())) > 1


def test_auto_plan_compresses_in_one_calibration_pass(small_model,
                                                      monkeypatch):
    """`*=wanda@auto; budget=...` through compress_model runs EXACTLY
    n_layers * n_chunks layer forwards: the probe pass is the only
    calibration traffic, and the compression stage reuses its stats."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    calls = {"n": 0}
    orig = lm._layer_fwd

    def counted(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(lm, "_layer_fwd", counted)
    new, rows = compress_model(cfg, params, cal,
                               plan="*=wanda@auto; budget=0.6")
    assert calls["n"] == cfg.n_layers * 1
    assert len(rows) > 0
    assert all(s.method == "wanda" for s in rows)
    # requested CR records the allocator's decision; measured tracks it
    for s in rows:
        assert s.cr_requested > 0
        assert abs(s.cr - s.cr_requested) < 0.05
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


def test_auto_plan_without_allocation_raises(small_model):
    plan = CompressionPlan.parse("*=slab@auto; budget=0.5")
    assert plan.is_auto
    with pytest.raises(ValueError, match="auto"):
        plan.resolve(0, "attn.wq")
    # missing budget is a loud error too
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    with pytest.raises(ValueError, match="budget"):
        compress_model(cfg, params, cal, plan="*=slab@auto")


def test_allocate_infeasible_budget_raises(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    with pytest.raises(ValueError, match="infeasible"):
        allocate_plan(cfg, params, cal, budget=0.9, template="*=wanda",
                      ceiling=0.5)


def test_emitted_plan_is_concrete_and_preserves_pinned_rules(small_model):
    """@auto rules become exact per-(layer, path) cr rules; pinned and
    skip rules survive behind them; the plan round-trips through its
    DSL with identical resolution."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    alloc = allocate_plan(
        cfg, params, cal, budget=0.5,
        plan="attn.wq=wanda@cr=0.3; mlp.w_up=skip; *=sola@auto,softness=0.25")
    plan = alloc.plan
    assert not plan.is_auto
    # pinned rule kept its own cr, skip still skips
    assert plan.resolve(0, "attn.wq").scfg.cr == 0.3
    assert plan.resolve(0, "mlp.w_up") is None
    # allocated rules are concrete, carry non-auto options, sit in-budget
    r = plan.resolve(1, "mlp.w_down")
    assert r.method == "sola" and r.compressor.softness == 0.25
    assert 0.0 < r.scfg.cr < 1.0
    # pinned/skipped linears are excluded from the allocation
    allocated_paths = {row["path"] for row in alloc.rows}
    assert "attn.wq" not in allocated_paths
    assert "mlp.w_up" not in allocated_paths
    # DSL round-trip resolves identically
    re = CompressionPlan.parse(plan.to_dsl())
    for l in range(cfg.n_layers):
        for p in ("attn.wq", "attn.wo", "mlp.w_up", "mlp.w_down"):
            a, b = None, None
            try:
                a = plan.resolve(l, p)
            except ValueError:
                pass
            try:
                b = re.resolve(l, p)
            except ValueError:
                pass
            assert (a is None) == (b is None)
            if a is not None:
                assert a.method == b.method and a.scfg == b.scfg


def test_explicit_cr_rules_are_pinned_not_overridden(small_model):
    """In an unflagged plan, a rule carrying an explicit cr= is a pin:
    the allocator must not silently replace the user's choice."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    alloc = allocate_plan(cfg, params, cal, budget=0.5,
                          template="attn.wq=wanda@cr=0.2; *=wanda")
    assert "attn.wq" not in {r["path"] for r in alloc.rows}
    for l in range(cfg.n_layers):
        assert alloc.plan.resolve(l, "attn.wq").scfg.cr == 0.2
    _, rows = compress_model(cfg, params, None, plan=alloc.plan,
                             stats=alloc.stats)
    assert all(s.cr_requested == 0.2 for s in rows
               if s.name == "attn.wq")


def test_emitted_plan_roundtrips_with_full_equality(small_model):
    """parse(to_dsl()) == plan holds for allocator-emitted plans too
    (layer specs are emitted in the DSL's native string form)."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    alloc = allocate_plan(cfg, params, cal, budget=0.5, template="*=wanda")
    assert CompressionPlan.parse(alloc.plan.to_dsl()) == alloc.plan
    assert CompressionPlan.parse(alloc.plan.to_json()) == alloc.plan
    assert CompressionPlan.parse(repr(alloc.plan)) == alloc.plan


def test_budget_segment_without_auto_flag_still_allocates(small_model,
                                                          monkeypatch):
    """'*=wanda; budget=0.6' (no @auto flag) must not silently drop the
    budget: the pipeline routes it through the allocator, still in one
    calibration pass. Emitted plans stay concrete (no re-allocation)."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    plan = CompressionPlan.parse("*=wanda; budget=0.6")
    assert not plan.is_auto and plan.wants_allocation
    calls = {"n": 0}
    orig = lm._layer_fwd

    def counted(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(lm, "_layer_fwd", counted)
    _, rows = compress_model(cfg, params, cal, plan=plan)
    assert calls["n"] == cfg.n_layers
    assert len({round(s.cr_requested, 2) for s in rows}) > 1
    alloc = allocate_plan(cfg, params, cal, budget=0.6, template="*=wanda")
    assert not alloc.plan.wants_allocation


def test_malformed_bare_option_raises():
    """Only 'auto' is a bare flag; a forgotten '=value' fails at parse
    time instead of producing a True-valued hyper-parameter."""
    with pytest.raises(ValueError, match="bad option"):
        CompressionPlan.parse("*=slab@pattern")
    with pytest.raises(ValueError, match="bad option"):
        CompressionPlan.parse("*=wanda@cr0.5")


def test_shared_block_gets_one_cr():
    """Tied weights: every shared.* linear of the hybrid shared block
    lands in ONE allocation group with one CR."""
    cfg = configs.get("zamba2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    alloc = allocate_plan(cfg, params, cal, budget=0.5,
                          plan="shared.*=wanda@auto; *=skip; budget=0.5")
    assert set(alloc.crs) == {"shared"}
    shared_rows = [r for r in alloc.rows if r["path"].startswith("shared.")]
    assert {r["path"] for r in shared_rows} == set(shared_linear_paths(cfg))
    assert len({r["cr"] for r in shared_rows}) == 1
    # the emitted plan compresses exactly the shared block, once
    new, rows = compress_model(cfg, params, None, plan=alloc.plan,
                               stats=alloc.stats)
    assert sorted(s.name for s in rows) == sorted(shared_linear_paths(cfg))
    assert len({s.cr_requested for s in rows}) == 1


def test_layer_granularity_one_cr_per_layer(small_model):
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    alloc = allocate_plan(cfg, params, cal, budget=0.5, template="*=wanda",
                          granularity="layer")
    assert set(alloc.crs) == {f"L{l}" for l in range(cfg.n_layers)}
    by_layer = {}
    for row in alloc.rows:
        by_layer.setdefault(row["layer"], set()).add(row["cr"])
    assert all(len(v) == 1 for v in by_layer.values())


@pytest.mark.parametrize("arch", ["mamba2_1_3b", "deepseek_moe_16b"])
def test_allocator_other_families(arch):
    """SSM and MoE families allocate (3-D expert leaves probe
    per-expert) and hit the budget within a grid step."""
    cfg = configs.get(arch, smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    new, rows = compress_model(cfg, params, cal,
                               plan="*=wanda@auto; budget=0.5")
    assert len(rows) > 0
    assert abs(_global_cr(cfg, params, rows) - 0.5) < 0.06
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    logits, _ = lm.forward(cfg, new, t)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.slow
def test_allocated_slab_beats_uniform_end_to_end(small_model):
    """Full SLaB through the @auto path on a larger calibration set:
    the allocated plan still wins on summed err_after at equal (±1%)
    measured CR, and the whole flow stays one calibration pass."""
    cfg, params = small_model
    cal = calibration_batch(cfg.vocab, n_seq=8, seq_len=64)
    stats = collect_model_stats(cfg, params, cal, plan="*=slab")
    _, urows = compress_model(cfg, params, None,
                              plan="*=slab@cr=0.5,iters=4", stats=stats)
    alloc = allocate_plan(cfg, params, budget=0.5,
                          template="*=slab@iters=4", stats=stats)
    _, arows = compress_model(cfg, params, None, plan=alloc.plan,
                              stats=alloc.stats)
    err_u = sum(s.err_after for s in urows)
    err_a = sum(s.err_after for s in arows)
    assert err_a <= err_u, (err_a, err_u)
    assert abs(_global_cr(cfg, params, arows)
               - _global_cr(cfg, params, urows)) <= 0.01
