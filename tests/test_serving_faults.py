"""Fault-tolerant serving: request lifecycle statuses, deadlines,
load shedding, starvation caps, the per-row finite-logits guard, and
the deterministic fault-injection harness (``serving/faults.py``).

The e2e invariant everywhere: under injected faults, SURVIVING streams
stay token-exact vs per-request ``greedy_decode`` (greedy determinism
makes every recompute-replay verifiable), quarantined/expired streams
keep a valid greedy PREFIX as partial output, no KV blocks leak, and
``Engine.run`` never raises on a valid trace — failures are statuses.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.launch.serve import greedy_decode
from repro.models import lm
from repro.serving import (BlockAllocator, Engine, EngineConfig,
                           FaultEvent, FaultPlan, Request, Scheduler,
                           summarize)
from repro.serving.faults import BURST_RID_BASE, BurstSpec


# ----------------------------------------------------------------------
# Allocator fault surface (reserve / release) + leak invariants
# ----------------------------------------------------------------------

def test_allocator_reserve_caps_at_free():
    a = BlockAllocator(8)
    held = a.alloc(5)
    assert a.reserve(10) == 3            # only 3 were free
    assert a.n_free == 0 and a.n_reserved == 3
    assert a.alloc(1) is None            # reserved blocks aren't free
    a.free(held)
    assert a.release() == 3
    assert a.n_free == 8 and a.n_reserved == 0


def test_allocator_reserve_release_partial():
    a = BlockAllocator(6)
    a.reserve(4)
    assert a.release(2) == 2
    assert a.n_free == 4 and a.n_reserved == 2
    a.release()
    assert a.n_free == 6


def test_allocator_double_free_raises_after_reserve_cycle():
    a = BlockAllocator(4)
    ids = a.alloc(2)
    a.reserve(2)
    a.free(ids)
    with pytest.raises(ValueError):
        a.free(ids)
    a.release()
    assert a.n_free == 4


# ----------------------------------------------------------------------
# Scheduler lifecycle units (no model)
# ----------------------------------------------------------------------

def _req(rid, p_len, max_new=4, arrival=0.0, deadline=None):
    return Request(rid=rid, prompt=np.full(p_len, rid + 1, np.int32),
                   max_new=max_new, arrival=arrival, deadline=deadline)


def test_status_transitions_through_lifecycle():
    s = Scheduler(n_slots=1, n_blocks=8, block_size=4, max_len=32)
    r = _req(0, 6, max_new=1)
    assert s.submit(r) is True and r.status == "queued"
    s.admit(0.0)
    assert r.status == "running"
    _, n_valid, _ = s.plan_step()
    s.commit_step(n_valid, np.array([42]), now=1.0)
    assert r.status == "finished" and r.terminal and r.finish == 1.0


def test_submit_keeps_pending_sorted_by_arrival():
    """bisect.insort admission queue: out-of-order submissions land
    sorted; equal arrivals stay FIFO (insort is right-biased)."""
    s = Scheduler(n_slots=1, n_blocks=8, block_size=4, max_len=32)
    for rid, t in [(0, 5.0), (1, 1.0), (2, 3.0), (3, 1.0), (4, 0.5)]:
        s.submit(_req(rid, 4, arrival=t))
    assert [r.rid for r in s.pending] == [4, 1, 3, 2, 0]
    assert s.next_arrival() == 0.5


def test_deadline_expires_waiting_and_running():
    s = Scheduler(n_slots=1, n_blocks=16, block_size=4, max_len=32)
    run = _req(0, 6, max_new=8, arrival=0.0, deadline=5.0)
    wait = _req(1, 6, max_new=8, arrival=0.0, deadline=3.0)
    s.submit(run), s.submit(wait)
    s.admit(0.0)
    _, n_valid, _ = s.plan_step()
    s.commit_step(n_valid, np.array([7]), now=1.0)
    assert run.status == "running" and run.out == [7]
    assert s.expire(2.0) == []           # nobody late yet
    timed = s.expire(6.0)                # both deadlines passed
    assert sorted(r.rid for r in timed) == [0, 1]
    assert run.status == wait.status == "timeout"
    assert run.out == [7]                # partial output survives
    assert not s.slots and not s.waiting
    assert s.alloc.n_free == 16          # running row freed its blocks


def test_eviction_cap_starves_instead_of_thrashing():
    s = Scheduler(n_slots=1, n_blocks=8, block_size=4, max_len=32,
                  max_evictions=1)
    r = _req(0, 6, max_new=4)
    s.submit(r)
    s.admit(0.0)
    s.plan_step()
    s.evict(0)                           # within budget: requeued
    assert r.status == "queued" and r.n_evictions == 1
    assert s.waiting == [r]
    s.admit(1.0)
    s.plan_step()
    s.evict(0)                           # over budget: starved out
    assert r.status == "failed" and "starved" in r.error
    assert not s.waiting and s.alloc.n_free == 8


def test_load_shed_reject_policy():
    s = Scheduler(n_slots=1, n_blocks=16, block_size=4, max_len=32,
                  max_waiting=1, shed="reject")
    a, b, c = _req(0, 4), _req(1, 4), _req(2, 4)
    for r in (a, b, c):
        s.submit(r)
    s.admit(0.0)                         # a runs, b waits, c sheds
    assert a.status == "running"
    assert b.status == "queued" and s.waiting == [b]
    assert c.status == "shed" and "full" in c.error


def test_load_shed_evict_oldest_waiting_policy():
    s = Scheduler(n_slots=1, n_blocks=16, block_size=4, max_len=32,
                  max_waiting=1, shed="evict-oldest-waiting")
    a, b, c = _req(0, 4), _req(1, 4), _req(2, 4)
    for r in (a, b, c):
        s.submit(r)
    s.admit(0.0)                         # a runs, b displaced by c
    assert a.status == "running"
    assert b.status == "shed" and "displaced" in b.error
    assert s.waiting == [c] and c.status == "queued"


def test_diagnose_stall_names_request_and_blocks():
    s = Scheduler(n_slots=1, n_blocks=8, block_size=4, max_len=32)
    s.submit(_req(7, 10, max_new=4))
    s.alloc.reserve(8)
    s.admit(0.0)                         # watermark blocks admission
    diag = s.diagnose_stall()
    assert "rid=7" in diag and "needs 3 blocks" in diag
    assert "0/8 free" in diag and "8 reserved" in diag
    s.alloc.release()
    assert s.diagnose_stall() is None


# ----------------------------------------------------------------------
# FaultPlan units
# ----------------------------------------------------------------------

def test_fault_plan_is_seed_deterministic():
    a = FaultPlan.chaos(seed=11, vocab=128, n_rows=4)
    b = FaultPlan.chaos(seed=11, vocab=128, n_rows=4)
    assert a.events == b.events
    c = FaultPlan.chaos(seed=12, vocab=128, n_rows=4)
    assert a.events != c.events
    # every fault kind is represented in the canned mix
    kinds = {ev.kind for ev in a.events}
    assert {"nan", "pool_shrink", "pool_restore", "burst"} <= kinds


def test_fault_plan_lookup_and_validation():
    plan = FaultPlan([
        FaultEvent(step=3, kind="nan", rows=(0, 2)),
        FaultEvent(step=3, kind="nan", rows=(1,)),
        FaultEvent(step=5, kind="pool_restore"),
    ])
    assert plan.nan_rows(3) == (0, 2, 1)
    assert plan.nan_rows(4) == ()
    assert plan.has_restore_after(4) and not plan.has_restore_after(5)
    assert plan.max_step == 5
    with pytest.raises(ValueError):
        FaultEvent(step=1, kind="meteor-strike")


def test_burst_spec_materializes_fresh_requests():
    spec = BurstSpec(rid=BURST_RID_BASE, prompt=(1, 2, 3), max_new=2,
                     ttl=4.0)
    r1, r2 = spec.materialize(10.0), spec.materialize(10.0)
    assert r1 is not r2                  # replays never share state
    assert r1.arrival == 10.0 and r1.deadline == 14.0
    np.testing.assert_array_equal(r1.prompt, [1, 2, 3])


# ----------------------------------------------------------------------
# End-to-end chaos traces (model involved). Teardown asserts the block
# pool leaked nothing — the allocator invariant for EVERY trace.
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture
def make_engine():
    """Engine factory whose teardown runs the leak check on every
    engine a test built: all streams terminal, nothing reserved, and
    every block back on the free list."""
    engines = []

    def factory(cfg, params, ecfg):
        eng = Engine(cfg, params, ecfg)
        engines.append(eng)
        return eng

    yield factory
    for eng in engines:
        assert not eng.sched.slots, "slots still occupied after trace"
        assert eng.sched.alloc.n_reserved == 0, "reserved blocks leaked"
        assert eng.sched.alloc.n_free == eng.ecfg.n_blocks, "block leak"


def _trace(cfg, specs, seed=0, deadlines=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=p,
                                        dtype=np.int64).astype(np.int32),
                    max_new=n, arrival=a,
                    deadline=None if deadlines is None else deadlines[i])
            for i, (p, n, a) in enumerate(specs)]


def _greedy(cfg, params, req):
    return np.asarray(greedy_decode(
        cfg, params, jnp.asarray(req.prompt)[None, :], req.max_new))[0]


def _assert_exact(cfg, params, reqs):
    for r in reqs:
        want = _greedy(cfg, params, r)
        assert np.array_equal(np.asarray(r.out, np.int32), want), (
            f"rid={r.rid}: engine {r.out} != greedy {list(want)}")


def _assert_prefix(cfg, params, req):
    want = _greedy(cfg, params, req)
    got = np.asarray(req.out, np.int32)
    assert np.array_equal(got, want[:len(got)]), (
        f"rid={req.rid}: partial {req.out} not a greedy prefix")


def test_rejected_request_does_not_kill_trace(dense_setup, make_engine):
    cfg, params = dense_setup
    reqs = _trace(cfg, [(8, 4, 0.0), (60, 4, 0.0), (6, 5, 1.0)])
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=2, n_blocks=16, block_size=4, max_len=32,
        prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=500)
    by_rid = {r.rid: r for r in done}
    assert by_rid[1].status == "rejected" and by_rid[1].out == []
    assert by_rid[0].status == by_rid[2].status == "finished"
    _assert_exact(cfg, params, [by_rid[0], by_rid[2]])


def test_deadline_timeout_keeps_greedy_prefix(dense_setup, make_engine):
    cfg, params = dense_setup
    reqs = _trace(cfg, [(6, 6, 0.0), (12, 10, 0.0)],
                  deadlines=[None, 5.0])
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=2, n_blocks=16, block_size=4, max_len=32,
        prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=500)
    a, b = done[0], done[1]
    assert a.status == "finished"
    _assert_exact(cfg, params, [a])
    assert b.status == "timeout" and "deadline" in b.error
    assert 0 < b.n_generated < b.max_new
    _assert_prefix(cfg, params, b)


def test_max_steps_finalizes_instead_of_raising(dense_setup, make_engine):
    cfg, params = dense_setup
    reqs = _trace(cfg, [(6, 40, 0.0), (6, 40, 3.0), (6, 40, 100.0)])
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=1, n_blocks=16, block_size=4, max_len=64,
        prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=8)
    assert all(r.status == "timeout" for r in done)
    assert all("max_steps" in r.error for r in done)
    running = done[0]                    # was mid-decode when cut off
    assert 0 < running.n_generated < running.max_new
    _assert_prefix(cfg, params, running)


def test_forced_nan_retries_once_token_exact(dense_setup, make_engine):
    """One injected non-finite step: the victim replays through the
    recompute eviction path and every stream still matches greedy."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(6, 8, 0.0), (7, 8, 0.0)], seed=1)
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=2, n_blocks=16, block_size=4, max_len=32,
        prefill_chunk=4))
    faults = FaultPlan([FaultEvent(step=4, kind="nan", rows=(0,))])
    done = eng.run(reqs, clock="steps", max_steps=500, faults=faults)
    assert all(r.status == "finished" for r in done)
    assert sum(r.n_nan_retries for r in done) == 1
    assert sum(r.n_evictions for r in done) >= 1   # the retry path
    _assert_exact(cfg, params, done)


def test_persistent_nan_quarantines_victim_only(dense_setup, make_engine):
    """A row that stays non-finite after its replay is quarantined as
    failed with a greedy-prefix partial output; its fused-batch
    neighbor never notices."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(6, 10, 0.0), (7, 10, 0.0)], seed=2)
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=2, n_blocks=24, block_size=4, max_len=32,
        prefill_chunk=4))
    faults = FaultPlan([FaultEvent(step=s, kind="nan", rows=(0,))
                        for s in range(5, 40)])
    done = eng.run(reqs, clock="steps", max_steps=500, faults=faults)
    victim, neighbor = done[0], done[1]
    assert victim.status == "failed" and "non-finite" in victim.error
    assert victim.n_nan_retries == 1     # retried once, then failed
    assert 0 < victim.n_generated < victim.max_new
    _assert_prefix(cfg, params, victim)
    assert neighbor.status == "finished"
    _assert_exact(cfg, params, [neighbor])


def test_pool_shrink_evicts_and_recovers_exact(dense_setup, make_engine):
    """Allocator-pressure fault: a mid-trace pool shrink forces the
    evict-with-recompute path; every stream still finishes token-exact
    and the reserved blocks come back."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(8, 8, 0.0), (8, 8, 0.0)], seed=3)
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=2, n_blocks=8, block_size=4, max_len=16,
        prefill_chunk=4))
    faults = FaultPlan([
        FaultEvent(step=3, kind="pool_shrink", n_blocks=2),
        FaultEvent(step=60, kind="pool_restore"),
    ])
    done = eng.run(reqs, clock="steps", max_steps=1000, faults=faults)
    assert all(r.status == "finished" for r in done)
    assert eng.sched.n_evictions > 0
    _assert_exact(cfg, params, done)


def test_burst_injection_load_sheds(dense_setup, make_engine):
    """An injected arrival burst overflows the bounded waiting queue:
    overflow is shed with a status, admitted streams stay exact, and
    the burst requests come back in the returned trace."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(6, 6, 0.0), (6, 6, 0.0)], seed=4)
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=1, n_blocks=16, block_size=4, max_len=32,
        prefill_chunk=4, max_waiting=1, shed="reject"))
    rng = np.random.default_rng(5)
    specs = tuple(BurstSpec(
        rid=BURST_RID_BASE + i,
        prompt=tuple(int(t) for t in rng.integers(0, cfg.vocab, size=5)),
        max_new=3) for i in range(2))
    faults = FaultPlan([FaultEvent(step=2, kind="burst", bursts=specs)])
    done = eng.run(reqs, clock="steps", max_steps=800, faults=faults)
    assert len(done) == 4                # originals + injected burst
    statuses = {r.rid: r.status for r in done}
    assert statuses[0] == statuses[1] == "finished"
    assert "shed" in {statuses[BURST_RID_BASE + i] for i in range(2)}
    _assert_exact(cfg, params,
                  [r for r in done if r.status == "finished"])


def test_permanent_stall_fails_head_with_diagnosis(dense_setup,
                                                   make_engine):
    """Nothing running, nothing arriving, head can't fit: the engine
    diagnoses immediately (request + block accounting in the error)
    instead of idle-spinning into a RuntimeError."""
    cfg, params = dense_setup
    reqs = _trace(cfg, [(8, 4, 0.0)])
    eng = make_engine(cfg, params, EngineConfig(
        n_slots=1, n_blocks=8, block_size=4, max_len=16,
        prefill_chunk=4))
    faults = FaultPlan([FaultEvent(step=0, kind="pool_shrink",
                                   n_blocks=8)])    # no restore: stuck
    done = eng.run(reqs, clock="steps", max_steps=100, faults=faults)
    r = done[0]
    assert r.status == "failed"
    assert "rid=0" in r.error and "blocked" in r.error
    assert "0/8 free" in r.error
    assert eng.n_steps < 50              # diagnosed, not idle-spun


def test_chaos_seed_reproduces_byte_identical_runs(dense_setup,
                                                   make_engine):
    """The determinism contract: same trace + same FaultPlan seed =>
    byte-identical per-request out/statuses across two fresh runs."""
    cfg, params = dense_setup
    specs = [(9, 10, 0.0), (12, 12, 1.0), (7, 12, 2.0), (10, 9, 3.0)]
    faults = FaultPlan.chaos(seed=7, vocab=cfg.vocab, n_rows=2,
                             horizon=24, burst_prompt=5, burst_new=2)
    runs = []
    for _ in range(2):
        eng = make_engine(cfg, params, EngineConfig(
            n_slots=2, n_blocks=12, block_size=4, max_len=32,
            prefill_chunk=4))
        done = eng.run(_trace(cfg, specs, seed=6), clock="steps",
                       max_steps=2000, faults=faults)
        runs.append({r.rid: (r.status, tuple(r.out), r.n_evictions,
                             r.error) for r in done})
    assert runs[0] == runs[1]
    assert len(runs[0]) > len(specs)     # burst requests are in there
    # and the chaos run still finishes real work
    assert any(s[0] == "finished" for s in runs[0].values())
