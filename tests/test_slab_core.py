"""Unit + property tests for the SLaB decomposition (paper Algorithm 1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:        # property tests skip without hypothesis
    from conftest import given, settings, strategies as st

from repro.core import baselines, packing, scores, slab, sparsity
from repro.core.apply import slab_linear, slab_linear_packed
from repro.core.slab import SLaBConfig


def _w(key, d_out, d_in):
    return jax.random.normal(jax.random.PRNGKey(key), (d_out, d_in),
                             jnp.float32) * 0.05


def _an(key, d_in, n=64):
    x = jax.random.normal(jax.random.PRNGKey(key), (n, d_in), jnp.float32)
    return scores.act_col_norms(x)


# ------------------------- Eq. 9/10 accounting -------------------------

@settings(max_examples=25, deadline=None)
@given(cr=st.sampled_from([0.5, 0.6, 0.7, 0.8]),
       d_out=st.sampled_from([64, 128, 160]),
       d_in=st.sampled_from([64, 128, 256]))
def test_cr_accounting_property(cr, d_out, d_in):
    """Achieved compression ratio == requested CR (Eq. 9) within one
    element's worth of rounding."""
    w = _w(0, d_out, d_in)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=cr, iters=2))
    achieved = slab.compression_ratio(dec, bits=16)
    # floor() in the group top-k can only under-fill -> achieved >= cr
    assert achieved >= cr - 1e-6
    assert achieved - cr < 16.0 / d_in + 1e-6   # one group element slack


def test_keep_fraction_matches_paper_formula():
    f = slab.keep_fraction(0.5, 16, 4096, 4096)
    assert abs(f - (1 - 0.5 - 1 / 16 - 1 / 4096 - 1 / 4096)) < 1e-12
    with pytest.raises(ValueError):
        slab.keep_fraction(0.95, 16, 64, 64)   # infeasible budget


# ----------------------- decomposition invariants ----------------------

def test_lowrank_factors_nonnegative():
    """Prop. 2: rank-1 factors of |Y| are entry-wise >= 0."""
    w = _w(1, 96, 160)
    dec = slab.slab_decompose(w, _an(2, 160), SLaBConfig(cr=0.5, iters=5))
    assert bool(jnp.all(dec.u >= 0)) and bool(jnp.all(dec.v >= 0))


def test_binary_is_pm1():
    w = _w(3, 64, 128)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=3))
    assert set(np.unique(np.asarray(dec.w_b))) <= {-1, 1}


def test_error_decreases_with_iterations():
    """Alternating optimization converges (Table II iterations trend)."""
    w = _w(4, 128, 256)
    an = _an(5, 256)
    errs = []
    for iters in (1, 5, 20):
        dec = slab.slab_decompose(w, an, SLaBConfig(cr=0.5, iters=iters))
        errs.append(float(slab.decomposition_error(w, dec, an)))
    assert errs[2] <= errs[0] + 1e-6
    assert errs[1] <= errs[0] + 1e-6


def test_slab_beats_wanda_same_budget():
    """The paper's core claim at the matrix level: at equal storage
    budget, SLaB reconstructs better than pruning alone."""
    w = _w(6, 128, 256)
    an = _an(7, 256)
    dec = slab.slab_decompose(w, an, SLaBConfig(cr=0.5, iters=10))
    err_slab = float(slab.decomposition_error(w, dec, an))
    wd = baselines.wanda_prune(w, an, 0.5)   # 50% nnz = same CR at b=16
    err_wanda = float(scores.weighted_fro_error(w, wd, an))
    assert err_slab < err_wanda


def test_rank0_equals_wanda():
    """Fig. 3: rank 0 (no W_L/W_B) degenerates to Wanda."""
    w = _w(8, 64, 128)
    an = _an(9, 128)
    cfg = SLaBConfig(cr=0.5, iters=1, include_binary=False,
                     include_lowrank=False)
    dec = slab.slab_decompose(w, an, cfg)
    keep = slab.keep_fraction(0.5, 16, 64, 128, include_binary=False,
                              include_lowrank=False)
    wd = baselines.wanda_prune(w, an, keep)
    np.testing.assert_allclose(np.asarray(dec.w_s), np.asarray(wd),
                               rtol=0, atol=1e-6)


# ------------------------------ sparsity -------------------------------

@settings(max_examples=30, deadline=None)
@given(keep=st.floats(0.1, 0.9),
       g_rows=st.sampled_from([1, 16, 32]),
       seed=st.integers(0, 5))
def test_group_topk_counts(keep, g_rows, seed):
    s = jnp.abs(_w(seed, 64, 128))
    mask = sparsity.group_topk_mask(s, keep, group=(g_rows, 0))
    gsz = g_rows * 128
    want = int(np.floor(keep * gsz))
    got = np.asarray(mask).reshape(64 // g_rows, -1).sum(1)
    assert (got == want).all()


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([2, 4]), seed=st.integers(0, 5))
def test_nm_mask_structure(n, seed):
    m = 2 * n                      # 2:4 and 4:8
    s = jnp.abs(_w(seed, 32, 64))
    mask = sparsity.nm_mask(s, n, m)
    per_group = np.asarray(mask).reshape(32, 64 // m, m).sum(-1)
    assert (per_group == n).all()


def test_nm_then_group_respects_both():
    w = _w(10, 64, 128)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2,
                                                  pattern="2:4"))
    nz = np.asarray(dec.w_s != 0)
    assert (nz.reshape(64, 32, 4).sum(-1) <= 2).all()
    keep = slab.keep_fraction(0.5, 16, 64, 128)
    assert (nz.sum(1) == int(np.floor(keep * 128))).all()


def test_infeasible_nm_budget_raises():
    with pytest.raises(ValueError):
        sparsity.prune_mask(jnp.ones((8, 8)), 0.9, pattern="2:4")


# ------------------------------ packing --------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10), d_in=st.sampled_from([32, 64, 128]))
def test_signbit_roundtrip(seed, d_in):
    w = _w(seed, 16, d_in)
    b = jnp.where(w >= 0, 1, -1).astype(jnp.int8)
    packed = packing.pack_sign_bits(b)
    assert packed.shape == (16, d_in // 32)
    out = packing.unpack_sign_bits(packed, d_in)
    assert bool(jnp.all(out == b))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10), n=st.sampled_from([2, 4]))
def test_nm_pack_roundtrip(seed, n):
    m = 2 * n
    w = _w(seed, 32, 64)
    mask = sparsity.nm_mask(jnp.abs(w), n, m)
    ws = jnp.where(mask, w, 0)
    p = packing.pack_nm(ws, n, m)
    out = packing.unpack_nm(p)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ws), atol=0)


def test_ell_pack_roundtrip():
    w = _w(11, 64, 128)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    nnz = sparsity.mask_nnz_per_row_uniform(dec.w_s != 0)
    assert nnz is not None          # (1, D_in) groups -> row-uniform
    p = packing.ell_pack(dec.w_s, nnz)
    np.testing.assert_allclose(np.asarray(packing.ell_unpack(p)),
                               np.asarray(dec.w_s), atol=0)


def test_packed_bits_match_eq9():
    """Packed storage cost stays within the CR budget of Eq. 9."""
    d_out, d_in, cr, b = 128, 256, 0.5, 16
    w = _w(12, d_out, d_in)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=cr, iters=3))
    bits = slab.compressed_bits(dec, bits=b)
    assert bits <= (1 - cr) * b * d_out * d_in + b  # <= budget


# ------------------------------ forward --------------------------------

def test_forward_equivalence_paths():
    w = _w(13, 96, 160)
    an = _an(14, 160)
    x = jax.random.normal(jax.random.PRNGKey(15), (24, 160), jnp.float32)
    dec = slab.slab_decompose(w, an, SLaBConfig(cr=0.5, iters=4))
    dense = x @ slab.reconstruct(dec).T
    y1 = slab_linear(x, dec)
    y2 = slab_linear_packed(x, packing.pack_decomposition(dec))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(dense),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(dense),
                               atol=2e-4)


# ------------------------------ baselines ------------------------------

def test_sparsegpt_better_than_magnitude():
    """Hessian-aware pruning beats magnitude on the layer-output error
    ‖X(W−Ŵ)ᵀ‖_F — with *correlated* activations (the LLM regime; with
    isotropic X the Hessian is ≈ identity and there is nothing for OBS
    to exploit)."""
    w = _w(16, 64, 128)
    z = jax.random.normal(jax.random.PRNGKey(17), (256, 16), jnp.float32)
    a = jax.random.normal(jax.random.PRNGKey(18), (16, 128), jnp.float32)
    sc = jnp.exp(jax.random.normal(jax.random.PRNGKey(19), (128,)))
    x = (z @ a) * sc[None, :] + \
        0.1 * jax.random.normal(jax.random.PRNGKey(20), (256, 128))
    ws = baselines.sparsegpt_prune(w, x.T @ x, 0.5)
    wm = baselines.magnitude_prune(w, 0.5)
    err_s = float(jnp.linalg.norm(x @ (w - ws).T))
    err_m = float(jnp.linalg.norm(x @ (w - wm).T))
    assert err_s < err_m
    assert abs(float(jnp.mean(ws != 0)) - 0.5) < 0.02


def test_sparsegpt_nm_pattern():
    w = _w(18, 32, 64)
    x = jax.random.normal(jax.random.PRNGKey(19), (128, 64), jnp.float32)
    ws = baselines.sparsegpt_prune(w, x.T @ x, 0.5, pattern="2:4")
    nz = np.asarray(ws != 0).reshape(32, 16, 4).sum(-1)
    assert (nz <= 2).all()


def test_streaming_act_norms():
    x = jax.random.normal(jax.random.PRNGKey(20), (96, 32), jnp.float32)
    acc = scores.ActNormAccumulator(32)
    for i in range(0, 96, 32):
        acc.update(x[i:i + 32])
    np.testing.assert_allclose(np.asarray(acc.norms()),
                               np.asarray(scores.act_col_norms(x)),
                               rtol=1e-5)
