"""Heterogeneous packed serving: mixed-method plans pack EVERY linear
into a variant-tagged format and forward through the per-variant fused
kernels (interpret mode on CPU) — partial coverage, mixed N:M patterns,
rank-r low-rank, sparse-only and binary+low-rank variants included."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import compressor as compressor_lib
from repro.core.apply import slab_linear
from repro.core.packed_model import (PackedLinear, PackedStack,
                                     pack_linear, pack_plan_decs,
                                     packed_matmul, variant_of)
from repro.core.pipeline import compress_model, linear_paths
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig, SLaBDecomposition
from repro.core.sparsity import prune_mask
from repro.data import calibration_batch
from repro.models import lm
from repro.models.common import positions_for

MIXED_PLAN = ("attn.*=sparsegpt@pattern=2:4; mlp.w_gate=hassle@rank=4; "
              "*=slab")


def _cfg(arch="stablelm_12b", **kw):
    return configs.get(arch, smoke=True).with_(dtype=jnp.float32, **kw)


def _compress_packed(cfg, plan_spec, seed=0, iters=2):
    params, _ = lm.init(cfg, jax.random.PRNGKey(seed))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    plan = CompressionPlan.parse(plan_spec,
                                 base=SLaBConfig(cr=0.5, iters=iters))
    dense_c, stats, decs = compress_model(cfg, params, cal, plan=plan,
                                          keep_decompositions=True)
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers, plan)
    return dense_c, packed, rep, stats, decs


def _max_rel(a, b):
    return (float(jnp.max(jnp.abs(a - b)))
            / max(float(jnp.max(jnp.abs(a))), 1e-12))


@pytest.fixture(scope="module")
def mixed_setup():
    cfg = _cfg()
    dense_c, packed, rep, stats, decs = _compress_packed(cfg, MIXED_PLAN)
    return cfg, dense_c, packed, rep, stats, decs


def test_mixed_plan_zero_dense_fallback(mixed_setup):
    """The acceptance-criteria property: every linear of a mixed
    sparsegpt/hassle/slab plan serves on the fused kernel path."""
    cfg, _, packed, rep, stats, decs = mixed_setup
    n_lin = cfg.n_layers * len(linear_paths(cfg))
    assert len(decs) == n_lin            # pruning methods keep decs too
    assert rep.n_packed == n_lin
    assert rep.fallback == []
    # attn.{wq,wk,wv,wo} -> N:M sparsegpt; mlp.w_gate -> rank-4 hassle;
    # mlp.{w_up,w_down} -> full SLaB. The unstructured sparse parts
    # (keep ≈ 0.43-0.45 at CR 0.5) route to row-padded ELL — the format
    # that finally beats dense bytes for them.
    assert rep.by_variant == {"sparse-nm": 4 * cfg.n_layers,
                              "lowrank-ell": cfg.n_layers,
                              "slab-ell": 2 * cfg.n_layers}
    # every packed variant now stores fewer bytes than dense (the old
    # slab-dense/lowrank-dense silently exceeded it)
    for var, (pb, db) in rep.bytes_by_variant.items():
        assert pb < db, (var, pb, db)
    # every (layer, path) stat carries its servable variant
    assert all(s.variant for s in stats)


def test_mixed_plan_fast_path_stays_scannable(mixed_setup):
    """Full-coverage single-variant paths stack into plain PackedLinears
    (the lax.scan fast path) — no PackedStack, no unrolling."""
    _, _, packed, _, _, _ = mixed_setup
    leaves = jax.tree.leaves(
        packed["layers"],
        is_leaf=lambda x: isinstance(x, (PackedLinear, PackedStack)))
    assert any(isinstance(l, PackedLinear) for l in leaves)
    assert not any(isinstance(l, PackedStack) for l in leaves)
    wg = packed["layers"]["mlp"]["w_gate"]
    assert wg.variant == "lowrank-ell" and wg.rank == 4


def test_mixed_packed_forward_matches_dense(mixed_setup):
    cfg, dense_c, packed, _, _, _ = mixed_setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


def test_mixed_packed_decode_matches_dense(mixed_setup):
    cfg, dense_c, packed, _, _, _ = mixed_setup
    b, s = 2, 4
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    cd = lm.init_cache(cfg, b, s)
    cp = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


def test_acceptance_plan_serves_fully_packed():
    """The issue's acceptance plan, verbatim: sparsegpt@2:4 attention +
    rank-4 hassle MLPs + slab catch-all packs every linear and matches
    the dense-applied forward in interpret mode."""
    cfg = _cfg()
    dense_c, packed, rep, _, _ = _compress_packed(
        cfg, "attn.*=sparsegpt@pattern=2:4; mlp.*=hassle@rank=4; *=slab")
    assert rep.fallback == []
    assert rep.n_packed == cfg.n_layers * len(linear_paths(cfg))
    assert set(rep.by_variant) == {"sparse-nm", "lowrank-ell"}
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


# ------------------------------------------------------------------
# Partial coverage + mixed patterns per path (the lifted restrictions)
# ------------------------------------------------------------------

HETERO_PLAN = ("0/attn.wq=skip; 0/attn.wk=slab@pattern=2:4; "
               "attn.wk=slab@pattern=4:8; *=slab")


@pytest.fixture(scope="module")
def hetero_setup():
    cfg = _cfg()
    dense_c, packed, rep, stats, decs = _compress_packed(cfg, HETERO_PLAN)
    return cfg, dense_c, packed, rep


def test_partial_coverage_and_mixed_patterns_pack(hetero_setup):
    """Regression for the pat_of[(0, name)] KeyError: attn.wq layer 0 is
    skipped (not servable) and attn.wk's pattern differs per layer —
    both previously fell back to dense for the whole path."""
    cfg, _, packed, rep = hetero_setup
    n_lin = cfg.n_layers * len(linear_paths(cfg))
    assert rep.n_packed == n_lin - 1          # only L0/attn.wq is dense
    assert rep.fallback == []
    assert rep.by_variant["slab-nm"] == 2     # 2:4 at L0, 4:8 at L1
    wq = packed["layers"]["attn"]["wq"]
    assert isinstance(wq, PackedStack)
    assert wq.dense_members == (0,) and wq.members == ((1,),)
    assert isinstance(wq.at_layer(0), jax.Array)     # dense leaf
    assert wq.at_layer(1).variant == "slab-ell"
    wk = packed["layers"]["attn"]["wk"]
    assert isinstance(wk, PackedStack) and wk.dense is None
    pats = {g.m_pat for g in wk.groups}
    assert pats == {4, 8} and wk.variant_counts() == {"slab-nm": 2}


def test_hetero_forward_and_decode_match_dense(hetero_setup):
    """PackedStack leaves route the model through the unrolled layer
    loop; numerics must match the scanned dense-equivalent path."""
    cfg, dense_c, packed, _ = hetero_setup
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4
    b, s = 2, 3
    cd = lm.init_cache(cfg, b, s)
    cp = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


def test_ssm_hetero_decode_matches_dense():
    """Unrolled decode on the SSM family (stacked mamba caches restack
    correctly across the Python layer loop)."""
    cfg = _cfg("mamba2_1_3b")
    dense_c, packed, rep, _, _ = _compress_packed(
        cfg, "0/mamba.out=skip; *=slab")
    assert isinstance(packed["layers"]["mamba"]["out"], PackedStack)
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 3), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4
    cd = lm.init_cache(cfg, 2, 3)
    cp = lm.init_cache(cfg, 2, 3)
    for t in range(3):
        pos = positions_for(cfg, 2, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


@pytest.mark.slow
def test_hybrid_hetero_decode_matches_dense():
    """Unrolled decode on the hybrid family: the shared transformer
    block fires at the right layers and its stacked KV caches update
    in place across the Python layer loop."""
    cfg = _cfg("zamba2_7b", n_layers=3)        # shared block at layer 2
    dense_c, packed, rep, _, _ = _compress_packed(
        cfg, "0/mamba.out=skip; *=slab")
    assert isinstance(packed["layers"]["mamba"]["out"], PackedStack)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 3), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4
    cd = lm.init_cache(cfg, 2, 3)
    cp = lm.init_cache(cfg, 2, 3)
    for t in range(3):
        pos = positions_for(cfg, 2, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


# ------------------------------------------------------------------
# Variant round-trips (packed_matmul == dense-applied decomposition)
# ------------------------------------------------------------------

def _dec(seed, n=64, k=128, *, sparse="dense", rank=0, binary=False,
         keep=0.4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(ks[0], (n, k), jnp.float32) * 0.1
    if sparse is None:
        w_s = jnp.zeros((n, k), jnp.float32)
    elif sparse == "nm":
        w_s = jnp.where(prune_mask(jnp.abs(w), 0.5, pattern="2:4"), w, 0.0)
    else:
        w_s = jnp.where(prune_mask(jnp.abs(w), keep), w, 0.0)
    if rank:
        u = jax.random.normal(ks[1], (n, rank), jnp.float32) * 0.2
        v = jax.random.normal(ks[2], (k, rank), jnp.float32) * 0.2
    else:
        u = jnp.zeros((n, 0), jnp.float32)
        v = jnp.zeros((k, 0), jnp.float32)
    if binary:
        w_b = jnp.where(jax.random.bernoulli(ks[3], 0.5, (n, k)),
                        1, -1).astype(jnp.int8)
    else:
        w_b = jnp.zeros((0, 0), jnp.int8)
    return SLaBDecomposition(w_s, u, v, w_b)


@pytest.mark.parametrize(
    "kw,pattern,variant",
    [(dict(sparse="nm", rank=2, binary=True), "2:4", "slab-nm"),
     (dict(sparse="dense", rank=3, binary=True), None, "slab-ell"),
     (dict(sparse="dense", rank=3, binary=True, keep=0.75), None,
      "slab-dense"),
     (dict(sparse=None, rank=2, binary=True), None, "binlr"),
     (dict(sparse="nm", rank=4), "2:4", "lowrank-nm"),
     (dict(sparse="dense", rank=4), None, "lowrank-ell"),
     (dict(sparse="dense", rank=4, keep=0.75), None, "lowrank-dense"),
     (dict(sparse=None, rank=3), None, "lowrank"),
     (dict(sparse="nm"), "2:4", "sparse-nm"),
     (dict(sparse="dense"), None, "sparse-ell"),
     (dict(sparse="dense", keep=0.75), None, "sparse-dense")],
    ids=lambda p: p if isinstance(p, str) else "")
def test_variant_roundtrip(kw, pattern, variant):
    dec = _dec(11, **kw)
    assert variant_of(dec, pattern) == variant
    pl = pack_linear(dec, pattern)
    assert pl.variant == variant
    x = jax.random.normal(jax.random.PRNGKey(12), (8, 128), jnp.float32)
    got = packed_matmul(x, pl, interpret=True)
    want = slab_linear(x, dec)                 # dense-applied oracle
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_binary_without_lowrank_serves_sparse_only():
    """W_L ⊙ W_B with empty W_L is identically zero (core.slab
    semantics): a lone binary term must not change the variant."""
    dec = _dec(13, sparse="dense", rank=0, binary=True)
    assert variant_of(dec, None) == "sparse-ell"
    x = jax.random.normal(jax.random.PRNGKey(14), (4, 128), jnp.float32)
    got = packed_matmul(x, pack_linear(dec, None), interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(x @ dec.w_s.T),
                               rtol=1e-5, atol=1e-5)


def test_pack_linear_rejects_pattern_mismatch():
    dec = _dec(15, sparse="dense")             # unstructured, not 2:4
    with pytest.raises(ValueError, match="not 2:4 sparse"):
        pack_linear(dec, "2:4")


# ------------------------------------------------------------------
# SoLA soft-activation-sparsity compressor
# ------------------------------------------------------------------

def test_sola_soft_prunes_on_wanda_support():
    w = jax.random.normal(jax.random.PRNGKey(20), (32, 64), jnp.float32)
    an = jnp.abs(jax.random.normal(jax.random.PRNGKey(21), (64,))) + 0.1
    stats = compressor_lib.LinearStats(norms=an)
    scfg = SLaBConfig(cr=0.5)
    sola = compressor_lib.get("sola", scfg, softness=0.5).compress(w, stats)
    wanda = compressor_lib.get("wanda", scfg).compress(w, stats)
    # same kept support as wanda, shrunk values, no extra zeros
    np.testing.assert_array_equal(np.asarray(sola.dense != 0),
                                  np.asarray(wanda.dense != 0))
    assert float(jnp.max(jnp.abs(sola.dense) - jnp.abs(wanda.dense))) <= 0
    assert float(jnp.min(jnp.where(sola.dense != 0,
                                   jnp.abs(sola.dense), jnp.inf))) > 0
    assert abs(sola.cr - 0.5) < 0.05
    # softness=0 is exactly wanda; decs pack as sparse-only
    hard = compressor_lib.get("sola", scfg, softness=0.0).compress(w, stats)
    np.testing.assert_allclose(np.asarray(hard.dense),
                               np.asarray(wanda.dense), rtol=1e-6)
    assert variant_of(sola.dec, None) == "sparse-ell"


def test_sola_registered_and_plan_selectable():
    assert "sola" in compressor_lib.available()
    plan = CompressionPlan.parse("mlp.*=sola@softness=0.25; *=slab")
    r = plan.resolve(0, "mlp.w_up")
    assert r.method == "sola" and r.compressor.softness == 0.25
    assert r.needs == frozenset({"norms"})
