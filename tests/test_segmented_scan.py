"""Segmented-scan heterogeneous serving: the layer axis of a mixed
packed plan partitions into maximal contiguous same-signature runs
(``segment_runs``), each driven by ONE ``lax.scan`` — numerics must
match both the per-layer 'unrolled' segmentation and the dense-applied
weights, and trace cost must be O(#segments), independent of depth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.packed_model import (PackedStack, layer_slice_range,
                                     segment_runs)
from repro.models import lm
from repro.models.common import positions_for

from benchmarks.common import (per_layer_segments as _unrolled,
                               synthetic_pruned_packed as _packed_prune)


def _cfg(arch="stablelm_12b", **kw):
    return configs.get(arch, smoke=True).with_(dtype=jnp.float32, **kw)


def _decode_seq(cfg, params, toks, segments=None):
    b, s = toks.shape
    cache = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        logits, cache = lm.decode_step(cfg, params, cache,
                                       toks[:, t:t + 1], pos,
                                       segments=segments)
    return logits, cache


# ------------------------------------------------------------------
# segment_runs unit behavior
# ------------------------------------------------------------------

def test_segment_runs_boundaries():
    cfg = _cfg(n_layers=6)
    _, packed, rep = _packed_prune(
        cfg, lambda l: 0.25 if l < 3 else 0.5,
        skip={(0, "attn.wq")})
    # layer 0: attn.wq dense remainder; 1-2: keep .25 groups; 3-5: keep .5
    assert segment_runs(packed["layers"], cfg.n_layers) == \
        ((0, 1), (1, 3), (3, 6))
    assert [(s.lo, s.hi) for s in rep.segments] == [(0, 1), (1, 3), (3, 6)]
    descs = dict(rep.segments[0].sig)
    assert descs["attn.wq"] == "dense"
    assert dict(rep.segments[1].sig)["attn.wq"].startswith("sparse-ell")


def test_segment_runs_homogeneous_is_one_run():
    cfg = _cfg(n_layers=4)
    _, packed, rep = _packed_prune(cfg, lambda l: 0.5)
    assert segment_runs(packed["layers"], cfg.n_layers) == ((0, 4),)
    assert len(rep.segments) == 1


def test_packed_stack_segment_slices():
    cfg = _cfg(n_layers=6)
    _, packed, _ = _packed_prune(
        cfg, lambda l: 0.25 if l < 3 else 0.5, skip={(0, "attn.wq")})
    wq = packed["layers"]["attn"]["wq"]
    assert isinstance(wq, PackedStack)
    seg = wq.segment(1, 3)
    assert seg.sparse_vals.shape[0] == 2
    with pytest.raises(ValueError, match="straddle"):
        wq.segment(2, 4)                      # crosses the keep boundary
    # per-segment tree slices stack every leaf to the run length
    sub = layer_slice_range(packed["layers"], 3, 6)
    assert sub["attn"]["wq"].sparse_vals.shape[0] == 3
    assert sub["attn_norm"].shape[0] == 3


# ------------------------------------------------------------------
# Parity: segmented == unrolled == dense (forward + decode)
# ------------------------------------------------------------------

def test_forward_segmented_matches_unrolled_and_dense():
    cfg = _cfg(n_layers=6)
    dense_c, packed, rep = _packed_prune(
        cfg, lambda l: 0.25 if l < 3 else 0.5, skip={(0, "attn.wq")})
    assert len(rep.segments) == 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    f_seg, _ = lm.forward(cfg, packed, toks)
    f_unr, _ = lm.forward(cfg, packed, toks,
                          segments=_unrolled(cfg.n_layers))
    f_dense, _ = lm.forward(cfg, dense_c, toks)
    np.testing.assert_allclose(np.asarray(f_seg), np.asarray(f_unr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(f_seg), np.asarray(f_dense),
                               rtol=1e-4, atol=1e-4)


def test_decode_segmented_matches_unrolled_and_dense():
    cfg = _cfg(n_layers=6)
    dense_c, packed, _ = _packed_prune(
        cfg, lambda l: 0.25 if l < 3 else 0.5, skip={(0, "attn.wq")})
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0, cfg.vocab)
    l_seg, c_seg = _decode_seq(cfg, packed, toks)
    l_unr, c_unr = _decode_seq(cfg, packed, toks,
                               segments=_unrolled(cfg.n_layers))
    l_dense, _ = _decode_seq(cfg, dense_c, toks)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_unr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_dense),
                               rtol=1e-4, atol=1e-4)
    # the per-segment cache concat restacks into the same stacked buffers
    for a, b in zip(jax.tree.leaves(c_seg), jax.tree.leaves(c_unr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_ssm_decode_segmented_matches_dense():
    """The fast tier already drives segmented SSM decode (decode_step's
    only path) via test_hetero_packing; this adds the unrolled-equality
    cross-check."""
    cfg = _cfg("mamba2_1_3b", n_layers=4)
    dense_c, packed, rep = _packed_prune(
        cfg, lambda l: 0.5, skip={(0, "mamba.out")})
    assert isinstance(packed["layers"]["mamba"]["out"], PackedStack)
    assert len(rep.segments) == 2
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 3), 0, cfg.vocab)
    l_seg, _ = _decode_seq(cfg, packed, toks)
    l_unr, _ = _decode_seq(cfg, packed, toks,
                           segments=_unrolled(cfg.n_layers))
    l_dense, _ = _decode_seq(cfg, dense_c, toks)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_unr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_dense),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.slow
def test_hybrid_shared_block_segmented_matches_dense():
    """zamba2: the shared transformer block fires inside a scanned
    segment (lax.cond path) and its stacked KV caches update in place
    across segment boundaries."""
    cfg = _cfg("zamba2_7b", n_layers=6)       # shared block at L2, L5
    dense_c, packed, rep = _packed_prune(
        cfg, lambda l: 0.5, skip={(0, "mamba.out")})
    assert len(rep.segments) == 2
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 3), 0, cfg.vocab)
    f_seg, _ = lm.forward(cfg, packed, toks)
    f_dense, _ = lm.forward(cfg, dense_c, toks)
    np.testing.assert_allclose(np.asarray(f_seg), np.asarray(f_dense),
                               rtol=1e-4, atol=1e-4)
    l_seg, c_seg = _decode_seq(cfg, packed, toks)
    l_unr, c_unr = _decode_seq(cfg, packed, toks,
                               segments=_unrolled(cfg.n_layers))
    l_dense, _ = _decode_seq(cfg, dense_c, toks)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_unr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(l_seg), np.asarray(l_dense),
                               rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree.leaves(c_seg.shared_kv),
                    jax.tree.leaves(c_unr.shared_kv)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------------
# Trace cost: O(#segments), not O(L)
# ------------------------------------------------------------------

def _fwd_traces(monkeypatch, cfg, packed):
    calls = {"n": 0}
    orig = lm._layer_fwd

    def wrapper(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    toks = jnp.zeros((1, 4), jnp.int32)
    with monkeypatch.context() as m:
        m.setattr(lm, "_layer_fwd", wrapper)
        jax.make_jaxpr(lambda p, t: lm.forward(cfg, p, t)[0])(packed, toks)
    return calls["n"]


def _decode_traces(monkeypatch, cfg, packed):
    calls = {"n": 0}
    orig = lm._layer_decode

    def wrapper(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    cache = lm.init_cache(cfg, 1, 2)
    tok = jnp.zeros((1, 1), jnp.int32)
    pos = positions_for(cfg, 1, 1)
    with monkeypatch.context() as m:
        m.setattr(lm, "_layer_decode", wrapper)
        jax.make_jaxpr(
            lambda p, c, t: lm.decode_step(cfg, p, c, t, pos)[0])(
                packed, cache, tok)
    return calls["n"]


def test_trace_count_scales_with_segments_not_depth(monkeypatch):
    """The heterogeneous path traces one layer body per scan segment:
    for a fixed segmentation shape the trace count is the 1-segment
    cost times #segments, and DOESN'T grow with n_layers."""
    keep3 = lambda l: 0.25 if l < 3 else 0.5         # noqa: E731
    cfg6 = _cfg(n_layers=6)
    _, packed6, rep6 = _packed_prune(cfg6, keep3, skip={(0, "attn.wq")})
    assert len(rep6.segments) == 3
    cfg1seg = _cfg(n_layers=6)
    _, packed1, rep1 = _packed_prune(cfg1seg, lambda l: 0.5)
    assert len(rep1.segments) == 1

    per_scan = _fwd_traces(monkeypatch, cfg1seg, packed1)
    assert per_scan >= 1                              # scan body cost
    n6 = _fwd_traces(monkeypatch, cfg6, packed6)
    assert n6 == 3 * per_scan

    # depth independence: same 3-segment shape at double the depth
    cfg12 = _cfg(n_layers=12)
    _, packed12, rep12 = _packed_prune(cfg12, keep3, skip={(0, "attn.wq")})
    assert len(rep12.segments) == 3
    assert _fwd_traces(monkeypatch, cfg12, packed12) == n6

    d6 = _decode_traces(monkeypatch, cfg6, packed6)
    d12 = _decode_traces(monkeypatch, cfg12, packed12)
    assert d6 == d12 == 3 * _decode_traces(monkeypatch, cfg1seg, packed1)


@pytest.mark.slow
def test_trace_count_full_depth_mixed_plan(monkeypatch):
    """Full-depth acceptance property: a 24-layer mixed plan with 3
    signature runs compiles O(#segments) layer bodies — strictly fewer
    than the O(L) the old unrolled path paid."""
    keep3 = lambda l: 0.25 if l < 8 else 0.5         # noqa: E731
    cfg = _cfg(n_layers=24)
    _, packed, rep = _packed_prune(cfg, keep3, skip={(0, "attn.wq")})
    assert len(rep.segments) == 3
    cfg1 = _cfg(n_layers=4)
    _, packed1, _ = _packed_prune(cfg1, lambda l: 0.5)
    per_scan = _fwd_traces(monkeypatch, cfg1, packed1)

    n = _fwd_traces(monkeypatch, cfg, packed)
    assert n == 3 * per_scan
    assert n < cfg.n_layers                          # O(#segments) ≪ L
    d = _decode_traces(monkeypatch, cfg, packed)
    assert d < cfg.n_layers
