"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing, slab
from repro.core.slab import SLaBConfig
from repro.kernels import ops, ref

SHAPES = [   # (M, N, K, bm, bn, bk)
    (32, 64, 128, 32, 32, 64),
    (64, 128, 256, 32, 64, 128),
    (128, 96, 320, 64, 32, 64),   # non-square, K not power of two
    (16, 256, 512, 16, 128, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(seed, m, n, k, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x = (jax.random.normal(kx, (m, k), jnp.float32)).astype(dtype)
    w = jax.random.normal(kw, (n, k), jnp.float32) * 0.05
    return x, w


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_binlr_matches_ref(shape, dtype):
    m, n, k, bm, bn, bk = shape
    x, w = _mk(0, m, n, k, dtype)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    pk = packing.pack_decomposition(dec)
    want = ref.binlr_ref(x.astype(jnp.float32), pk.b_packed, pk.u, pk.v)
    got = ops.binlr(x, pk.b_packed, pk.u, pk.v, bm=bm, bn=bn, bk=bk,
                    interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("pattern", ["2:4", "4:8"])
def test_nm_matmul_matches_ref(shape, pattern):
    m, n, k, bm, bn, bk = shape
    x, w = _mk(1, m, n, k, jnp.float32)
    dec = slab.slab_decompose(w, None,
                              SLaBConfig(cr=0.5, iters=2, pattern=pattern))
    pk = packing.pack_decomposition(dec, pattern=pattern)
    s = pk.sparse
    want = ref.nm_matmul_ref(x, s.values, s.indices, s.m)
    got = ops.nm_matmul(x, s.values, s.indices, s.m, bm=bm, bn=bn, bk=bk,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("dtype", DTYPES, ids=("f32", "bf16"))
def test_slab_matmul_fused_matches_ref(shape, dtype):
    m, n, k, bm, bn, bk = shape
    x, w = _mk(2, m, n, k, dtype)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    pk = packing.pack_decomposition(dec)
    ws = dec.w_s.astype(dtype)
    want = ref.slab_matmul_ref(x.astype(jnp.float32),
                               dec.w_s, pk.b_packed, pk.u, pk.v)
    got = ops.slab_matmul(x, ws, pk.b_packed, pk.u, pk.v,
                          bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_slab_nm_matmul_matches_ref(shape):
    m, n, k, bm, bn, bk = shape
    x, w = _mk(3, m, n, k, jnp.float32)
    dec = slab.slab_decompose(w, None,
                              SLaBConfig(cr=0.5, iters=2, pattern="2:4"))
    pk = packing.pack_decomposition(dec, pattern="2:4")
    s = pk.sparse
    want = ref.slab_nm_matmul_ref(x, s.values, s.indices, s.m,
                                  pk.b_packed, pk.u, pk.v)
    got = ops.slab_nm_matmul(x, s.values, s.indices, s.m,
                             pk.b_packed, pk.u, pk.v,
                             bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_kernel_vs_dense_reconstruction():
    """End to end: fused kernel == x @ Ŵᵀ for the real decomposition."""
    x, w = _mk(4, 64, 128, 256, jnp.float32)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=4))
    pk = packing.pack_decomposition(dec)
    dense = x @ slab.reconstruct(dec).T
    got = ops.slab_linear_kernel(x, pk, bm=32, bn=64, bk=64,
                                 interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(dense),
                               rtol=1e-4, atol=1e-4)


def _rank_factors(seed, n, k, r):
    ku, kv = jax.random.split(jax.random.PRNGKey(seed))
    return (jax.random.normal(ku, (n, r), jnp.float32) * 0.2,
            jax.random.normal(kv, (k, r), jnp.float32) * 0.2)


@pytest.mark.parametrize("rank", [2, 4])
def test_binlr_rank_r_matches_ref(rank):
    m, n, k, bm, bn, bk = 32, 64, 128, 32, 32, 64
    x, w = _mk(7, m, n, k, jnp.float32)
    bp = packing.pack_sign_bits(jnp.where(w >= 0, 1, -1).astype(jnp.int8))
    u, v = _rank_factors(8, n, k, rank)
    want = ref.binlr_ref(x, bp, u, v)
    got = ops.binlr(x, bp, u, v, bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rank", [2, 4])
def test_slab_matmul_rank_r_matches_ref(rank):
    """rank-r SLaB: the fused kernel accumulates r rank-1 binary terms
    against one streamed B tile."""
    m, n, k, bm, bn, bk = 32, 64, 128, 32, 32, 64
    x, w = _mk(9, m, n, k, jnp.float32)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    bp = packing.pack_sign_bits(dec.w_b)
    u, v = _rank_factors(10, n, k, rank)
    want = ref.slab_matmul_ref(x, dec.w_s, bp, u, v)
    got = ops.slab_matmul(x, dec.w_s, bp, u, v, bm=bm, bn=bn, bk=bk,
                          interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", SHAPES, ids=str)
@pytest.mark.parametrize("rank", [1, 4])
def test_slab_lr_matmul_matches_ref(shape, rank):
    """Sparse + rank-r low-rank, NO binary (HASSLE-free-style decs)."""
    m, n, k, bm, bn, bk = shape
    x, w = _mk(11, m, n, k, jnp.float32)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    u, v = _rank_factors(12, n, k, rank)
    want = ref.slab_lr_matmul_ref(x, dec.w_s, u, v)
    got = ops.slab_lr_matmul(x, dec.w_s, u, v, bm=bm, bn=bn, bk=bk,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("rank", [1, 4])
def test_slab_nm_lr_matmul_matches_ref(rank):
    m, n, k, bm, bn, bk = 32, 64, 128, 32, 32, 64
    x, w = _mk(13, m, n, k, jnp.float32)
    dec = slab.slab_decompose(w, None,
                              SLaBConfig(cr=0.5, iters=2, pattern="2:4"))
    pk = packing.pack_decomposition(dec, pattern="2:4")
    s = pk.sparse
    u, v = _rank_factors(14, n, k, rank)
    want = ref.slab_nm_lr_matmul_ref(x, s.values, s.indices, s.m, u, v)
    got = ops.slab_nm_lr_matmul(x, s.values, s.indices, s.m, u, v,
                                bm=bm, bn=bn, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_batched_leading_dims():
    """ops wrappers flatten (B, S, K) inputs."""
    x3 = jax.random.normal(jax.random.PRNGKey(5), (4, 8, 128), jnp.float32)
    _, w = _mk(6, 1, 64, 128, jnp.float32)
    dec = slab.slab_decompose(w, None, SLaBConfig(cr=0.5, iters=2))
    pk = packing.pack_decomposition(dec)
    got = ops.slab_matmul(x3, dec.w_s, pk.b_packed, pk.u, pk.v,
                          bm=32, bn=32, bk=64, interpret=True)
    assert got.shape == (4, 8, 64)
    want = ref.slab_matmul_ref(x3.reshape(-1, 128), dec.w_s, pk.b_packed,
                               pk.u, pk.v).reshape(4, 8, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
