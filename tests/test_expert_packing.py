"""Expert-axis packed serving: 3-D MoE leaves pack into K_max-bucketed
ExpertPackedStacks served by the grouped-expert fused kernels (interpret
mode on CPU), the hybrid shared block packs into plain PackedLinears,
and end-to-end MoE traces — including PR-7 engine eviction replay —
stay token-exact against the dense model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.apply import slab_linear
from repro.core.packed_model import (ExpertPackedStack, PackedLinear,
                                     PackedStack, expert_matmul,
                                     pack_expert_stack, pack_plan_decs)
from repro.core.pipeline import (collect_model_stats, compress_model,
                                 linear_paths)
from repro.core.plan import CompressionPlan
from repro.core.slab import SLaBConfig, SLaBDecomposition, reconstruct
from repro.core.sparsity import prune_mask
from repro.data import calibration_batch
from repro.launch.serve import greedy_decode
from repro.models import lm
from repro.models.common import positions_for
from repro.serving import Engine, EngineConfig, Request

EXPERT_PATHS = ("moe.w_gate", "moe.w_up", "moe.w_down")


def _cfg(arch="phi3_5_moe", **kw):
    return configs.get(arch, smoke=True).with_(dtype=jnp.float32, **kw)


def _compress_packed(cfg, plan_spec, seed=0, iters=2):
    params, _ = lm.init(cfg, jax.random.PRNGKey(seed))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    plan = CompressionPlan.parse(plan_spec,
                                 base=SLaBConfig(cr=0.5, iters=iters))
    dense_c, stats, decs = compress_model(cfg, params, cal, plan=plan,
                                          keep_decompositions=True)
    # the serve.py flow: hand the pipeline's classification through so
    # expert tuples short-circuit past the per-linear variants map
    packed, rep = pack_plan_decs(
        dense_c, decs, cfg.n_layers, plan,
        variants={(s.layer, s.name): s.variant for s in stats})
    return dense_c, packed, rep, stats, decs, plan


def _max_rel(a, b):
    return (float(jnp.max(jnp.abs(a - b)))
            / max(float(jnp.max(jnp.abs(a))), 1e-12))


@pytest.fixture(scope="module")
def moe_setup():
    cfg = _cfg()
    return (cfg,) + _compress_packed(cfg, "*=slab")


# ------------------------------------------------------------------
# pack_expert_stack units: bucketing, dense members, permutations
# ------------------------------------------------------------------

def _edec(seed, n=64, k=128, *, keep=0.4, rank=2, binary=True):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    w = jax.random.normal(ks[0], (n, k), jnp.float32) * 0.1
    w_s = jnp.where(prune_mask(jnp.abs(w), keep), w, 0.0)
    if rank:
        u = jax.random.normal(ks[1], (n, rank), jnp.float32) * 0.2
        v = jax.random.normal(ks[2], (k, rank), jnp.float32) * 0.2
    else:
        u = jnp.zeros((n, 0), jnp.float32)
        v = jnp.zeros((k, 0), jnp.float32)
    if binary:
        w_b = jnp.where(jax.random.bernoulli(ks[3], 0.5, (n, k)),
                        1, -1).astype(jnp.int8)
    else:
        w_b = jnp.zeros((0, 0), jnp.int8)
    return SLaBDecomposition(w_s, u, v, w_b)


def _unservable_dec(n=64, k=128):
    # no sparse plane at all: variant_of -> None (an all-ZERO w_s would
    # instead pack as a servable width-1 ELL serving zeros)
    return SLaBDecomposition(None,
                             jnp.zeros((n, 0), jnp.float32),
                             jnp.zeros((k, 0), jnp.float32),
                             jnp.zeros((0, 0), jnp.int8))


def test_mixed_kmax_buckets_pad_to_bucket_max():
    """Experts with very different realized row-nnz land in different
    K_max buckets: each bucket pads to ITS realized max, never the
    global one."""
    decs = tuple(_edec(s, keep=kp)
                 for s, kp in enumerate((0.05, 0.08, 0.4, 0.45)))
    old = jax.random.normal(jax.random.PRNGKey(9), (4, 128, 64))
    eps = pack_expert_stack(old, decs, None)
    assert isinstance(eps, ExpertPackedStack)
    assert eps.dense_members == () and eps.dense is None
    flat = sorted(e for mem in eps.members for e in mem)
    assert flat == [0, 1, 2, 3]
    assert len(eps.groups) >= 2             # sparse vs dense-ish buckets
    kmaxes = [int(jnp.max(jnp.sum(d.w_s != 0, -1))) for d in decs]
    for grp, mem in zip(eps.groups, eps.members):
        pad = grp.sparse_idx.shape[-1]
        assert pad == max(kmaxes[e] for e in mem)   # bucket-realized max
    x = jax.random.normal(jax.random.PRNGKey(10), (4, 8, 128))
    got = expert_matmul(x, eps, interpret=True)
    for e, d in enumerate(decs):
        np.testing.assert_allclose(np.asarray(got[e]),
                                   np.asarray(slab_linear(x[e], d)),
                                   rtol=1e-4, atol=1e-4)


def test_expert_stack_dense_member_and_permutation():
    """An unservable expert (no packable terms) rides the dense slice of
    ``old``; the bucket gather/scatter restores expert order even when
    groups interleave member ids."""
    decs = (_edec(0, keep=0.45), _unservable_dec(), _edec(2, keep=0.05),
            _edec(3, keep=0.45))
    old = jax.random.normal(jax.random.PRNGKey(11), (4, 128, 64)) * 0.1
    eps = pack_expert_stack(old, decs, None)
    assert eps.dense_members == (1,)
    assert eps.dense.shape == (1, 128, 64)
    assert 1 not in {e for mem in eps.members for e in mem}
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 8, 128))
    got = expert_matmul(x, eps, interpret=True)
    for e in (0, 2, 3):
        np.testing.assert_allclose(np.asarray(got[e]),
                                   np.asarray(slab_linear(x[e], decs[e])),
                                   rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(got[1]),
                               np.asarray(x[1] @ old[1]),
                               rtol=1e-4, atol=1e-4)


def test_single_bucket_full_coverage_fast_path():
    """Same-signature experts collapse to one group covering every
    expert id — the no-gather launch path."""
    decs = tuple(_edec(s, keep=0.4) for s in range(4))
    old = jax.random.normal(jax.random.PRNGKey(13), (4, 128, 64))
    eps = pack_expert_stack(old, decs, None)
    assert len(eps.groups) == 1 and eps.members == ((0, 1, 2, 3),)
    x = jax.random.normal(jax.random.PRNGKey(14), (4, 8, 128))
    got = expert_matmul(x, eps, interpret=True)
    for e, d in enumerate(decs):
        np.testing.assert_allclose(np.asarray(got[e]),
                                   np.asarray(slab_linear(x[e], d)),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------
# Full-model MoE packing + parity
# ------------------------------------------------------------------

def test_moe_packs_every_expert_zero_fallback(moe_setup):
    """The acceptance property: a full-coverage slab plan on an MoE
    model leaves NO dense-fallback linears — every expert of every 3-D
    leaf serves on a grouped kernel."""
    cfg, _, packed, rep, stats, _, _ = moe_setup
    assert rep.fallback == []
    n_expert = len(EXPERT_PATHS) * cfg.n_layers * cfg.n_experts
    n_2d = cfg.n_layers * (len(linear_paths(cfg)) - len(EXPERT_PATHS))
    assert rep.n_packed == n_2d + n_expert
    assert sum(rep.by_variant.values()) == rep.n_packed
    assert "dense-fallback" not in rep.bytes_by_variant
    for var, (pb, db) in rep.bytes_by_variant.items():
        assert pb < db, (var, pb, db)       # expert-packed bytes win too
    for p in EXPERT_PATHS:
        k = p.split(".")[1]
        assert isinstance(packed["layers"]["moe"][k],
                          (ExpertPackedStack, PackedStack))
        assert p in rep.paths
    # every 3-D leaf's stats row carries the expert classification
    assert all(s.variant == "expert" for s in stats
               if s.name in EXPERT_PATHS)


def test_moe_forward_matches_dense(moe_setup):
    cfg, dense_c, packed, _, _, _, _ = moe_setup
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


def test_moe_greedy_decode_token_exact(moe_setup):
    """Greedy decode through the grouped-expert kernels emits the SAME
    tokens as the dense-applied model at f32."""
    cfg, dense_c, packed, _, _, _, _ = moe_setup
    prompts = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                 cfg.vocab)
    want = np.asarray(greedy_decode(cfg, dense_c, prompts, 6))
    got = np.asarray(greedy_decode(cfg, packed, prompts, 6))
    assert np.array_equal(got, want)


def test_moe_decode_step_matches_dense(moe_setup):
    cfg, dense_c, packed, _, _, _, _ = moe_setup
    b, s = 2, 3
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab)
    cd = lm.init_cache(cfg, b, s)
    cp = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


def test_mixed_buckets_full_model_parity(moe_setup):
    """Force one expert into a lower K_max bucket than its peers (the
    ragged case) and check the whole forward still matches the
    dense-applied decompositions."""
    cfg, dense_c, _, _, _, decs, plan = moe_setup
    decs2 = dict(decs)
    tup = list(decs2[(0, "moe.w_up")])
    d0 = tup[0]
    w_s = jnp.where(prune_mask(jnp.abs(d0.w_s), 0.08), d0.w_s, 0.0)
    tup[0] = SLaBDecomposition(w_s, d0.u, d0.v, d0.w_b)
    decs2[(0, "moe.w_up")] = tuple(tup)
    dense2 = jax.tree.map(lambda a: a, dense_c)
    old = dense2["layers"]["moe"]["w_up"]
    w0 = reconstruct(tup[0]).T.astype(old.dtype)
    dense2["layers"]["moe"]["w_up"] = old.at[0, 0].set(w0)
    packed2, rep2 = pack_plan_decs(dense2, decs2, cfg.n_layers, plan)
    assert rep2.fallback == []
    leaf = packed2["layers"]["moe"]["w_up"]
    eps0 = leaf.at_layer(0) if isinstance(leaf, PackedStack) else leaf
    assert isinstance(eps0, ExpertPackedStack)
    assert len(eps0.groups) >= 2            # the re-pruned expert split off
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense2, toks)
    f_p, _ = lm.forward(cfg, packed2, toks)
    assert _max_rel(f_d, f_p) < 1e-4


def test_unservable_expert_reports_dense_fallback(moe_setup):
    """One expert with no packable terms: it serves from the dense
    slice, is named in the fallback list, its bytes land under the
    "dense-fallback" pseudo-variant, and parity still holds."""
    cfg, dense_c, _, _, _, decs, plan = moe_setup
    decs2 = dict(decs)
    tup = list(decs2[(0, "moe.w_down")])
    n, k = tup[1].w_s.shape
    tup[1] = _unservable_dec(n, k)
    decs2[(0, "moe.w_down")] = tuple(tup)
    packed2, rep2 = pack_plan_decs(dense_c, decs2, cfg.n_layers, plan)
    assert (0, "moe.w_down[expert 1]") in rep2.fallback
    pb, db = rep2.bytes_by_variant["dense-fallback"]
    assert pb == db > 0                     # still-dense bytes, reported
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed2, toks)
    assert _max_rel(f_d, f_p) < 1e-4


def test_zero_gram_expert_still_packs_and_matches():
    """An expert no calibration tokens route to (all-zero Gram) takes
    the identity-Hessian fallback: it must still produce a servable dec
    and match the dense-applied model."""
    cfg = _cfg()
    params, _ = lm.init(cfg, jax.random.PRNGKey(7))
    cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=16)
    plan = CompressionPlan.parse("moe.*=sparsegpt; *=slab",
                                 base=SLaBConfig(cr=0.5, iters=2))
    stats = collect_model_stats(cfg, params, cal, plan=plan)
    for l in range(cfg.n_layers):
        for p in EXPERT_PATHS:             # starve expert 2 everywhere
            if (l, p) in stats.hessians:
                stats.hessians[(l, p)] = \
                    stats.hessians[(l, p)].at[2].set(0.0)
            stats.norms[(l, p)] = stats.norms[(l, p)].at[2].set(0.0)
    dense_c, cstats, decs = compress_model(cfg, params, None, plan=plan,
                                           stats=stats,
                                           keep_decompositions=True)
    packed, rep = pack_plan_decs(dense_c, decs, cfg.n_layers, plan)
    assert rep.fallback == []              # identity fallback is servable
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 16), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


@pytest.mark.slow
def test_deepseek_shared_experts_pack_and_match():
    """DeepSeek-MoE geometry: routed experts pack on the expert axis
    while the always-on shared MLP packs as plain 2-D linears — zero
    fallback, forward parity."""
    cfg = _cfg("deepseek_moe_16b")
    dense_c, packed, rep, _, _, _ = _compress_packed(cfg, "*=slab")
    assert rep.fallback == []
    assert isinstance(packed["layers"]["moe"]["w_gate"],
                      (ExpertPackedStack, PackedStack))
    assert "moe.shared.w_gate" in rep.paths
    toks = jax.random.randint(jax.random.PRNGKey(6), (2, 8), 0, cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


# ------------------------------------------------------------------
# Hybrid shared block (zamba2): packed once, outside the layer stack
# ------------------------------------------------------------------

@pytest.fixture(scope="module")
def zamba_setup():
    cfg = _cfg("zamba2_7b", n_layers=3)     # shared block fires at L2
    return (cfg,) + _compress_packed(cfg, "*=slab")


def test_shared_block_packs_and_matches(zamba_setup):
    """Every shared.* linear becomes a PackedLinear inside
    params["shared_attn"] and the hybrid forward matches dense."""
    cfg, dense_c, packed, rep, _, _, _ = zamba_setup
    assert rep.fallback == []
    shared = [p for p in rep.paths if p.startswith("shared.")]
    assert len(shared) == 7                 # wq wk wv wo + swiglu mlp
    for p in shared:
        node = packed["shared_attn"]
        for part in p.split(".")[1:]:
            node = node[part]
        assert isinstance(node, PackedLinear), p
    toks = jax.random.randint(jax.random.PRNGKey(15), (2, 8), 0,
                              cfg.vocab)
    f_d, _ = lm.forward(cfg, dense_c, toks)
    f_p, _ = lm.forward(cfg, packed, toks)
    assert _max_rel(f_d, f_p) < 1e-4


@pytest.mark.slow
def test_shared_block_decode_matches_dense(zamba_setup):
    cfg, dense_c, packed, _, _, _, _ = zamba_setup
    b, s = 2, 3
    toks = jax.random.randint(jax.random.PRNGKey(16), (b, s), 0,
                              cfg.vocab)
    cd = lm.init_cache(cfg, b, s)
    cp = lm.init_cache(cfg, b, s)
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        ld, cd = lm.decode_step(cfg, dense_c, cd, toks[:, t:t + 1], pos)
        lp, cp = lm.decode_step(cfg, packed, cp, toks[:, t:t + 1], pos)
    assert _max_rel(ld, lp) < 1e-4


# ------------------------------------------------------------------
# PR-7 engine: eviction replay through the grouped-expert kernels
# ------------------------------------------------------------------

@pytest.mark.slow
def test_engine_eviction_replay_packed_moe(moe_setup):
    """A pool too small for all streams forces evict -> requeue ->
    recompute through the expert-packed model; greedy determinism makes
    the replay token-exact vs per-request greedy_decode."""
    cfg, _, packed, _, _, _, _ = moe_setup
    rng = np.random.default_rng(17)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=p,
                                        dtype=np.int64).astype(np.int32),
                    max_new=n, arrival=a)
            for i, (p, n, a) in enumerate([(10, 6, 0.0), (12, 6, 0.0),
                                           (8, 6, 0.0)])]
    eng = Engine(cfg, packed,
                 EngineConfig(n_slots=3, n_blocks=8, block_size=4,
                              max_len=32, prefill_chunk=4))
    done = eng.run(reqs, clock="steps", max_steps=2000)
    assert eng.sched.n_evictions > 0        # the point of this pool size
    for r in done:
        want = np.asarray(greedy_decode(
            cfg, packed, jnp.asarray(r.prompt)[None, :], r.max_new))[0]
        assert np.array_equal(np.asarray(r.out, np.int32), want), r.rid
