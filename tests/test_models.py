"""Per-arch smoke tests (reduced configs, CPU) + decode==forward
equivalence + family-specific behaviours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm
from repro.models.common import positions_for

ALL_ARCHS = configs.ARCH_IDS + configs.EXTRA_IDS


def _inputs(cfg, b, s, seed=1):
    if cfg.input_mode == "embeds" and cfg.family == "audio":
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 (b, s, cfg.d_model), jnp.float32)
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0,
                              cfg.vocab)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on the reduced config: output shapes
    + no NaNs (the assignment's per-arch smoke requirement)."""
    cfg = configs.get(arch, smoke=True)
    params, axes = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 64
    inputs = _inputs(cfg, b, s)
    logits, aux = lm.forward(cfg, params, inputs)
    assert logits.shape == (b, s, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))

    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                cfg.vocab)
    batch = {"inputs": inputs, "labels": labels}
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None, :, None], (b, s, 3))

    def loss(p):
        return lm.loss_fn(cfg, p, batch)[0]

    l0, grads = jax.value_and_grad(loss)(params)
    assert np.isfinite(float(l0))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0

    # one SGD step decreases loss on the same batch (sanity)
    params2 = jax.tree.map(
        lambda p, g: p - 0.5 * g.astype(p.dtype), params, grads)
    l1 = float(loss(params2))
    assert l1 < float(l0)


DECODE_ARCHS = [a for a in ALL_ARCHS
                if configs.get(a, smoke=True).family != "audio"]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    """Cached decode path == full forward (capacity non-binding for MoE:
    drops are the only legitimate divergence)."""
    cfg = configs.get(arch, smoke=True).with_(dtype=jnp.float32,
                                              capacity_factor=8.0)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 24
    inputs = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                cfg.vocab)
    full, _ = lm.forward(cfg, params, inputs)
    cache = lm.init_cache(cfg, b, s)
    dec = jax.jit(lambda c, t, p: lm.decode_step(cfg, params, c, t, p))
    outs = []
    for t in range(s):
        pos = positions_for(cfg, b, 1, offset=t)
        lg, cache = dec(cache, inputs[:, t:t + 1], pos)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    rel = float(jnp.max(jnp.abs(got - full))) / \
        float(jnp.max(jnp.abs(full)))
    assert rel < 2e-3, rel


def test_audio_encoder_is_bidirectional():
    cfg = configs.get("hubert_xlarge", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model))
    base, _ = lm.forward(cfg, params, x)
    x2 = x.at[:, -1].set(0.0)            # perturb the LAST frame
    pert, _ = lm.forward(cfg, params, x2)
    # non-causal: the FIRST frame's output must change too
    assert float(jnp.max(jnp.abs(pert[:, 0] - base[:, 0]))) > 1e-6


def test_causal_lm_is_causal():
    cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0, cfg.vocab)
    base, _ = lm.forward(cfg, params, t)
    t2 = t.at[:, -1].set((t[:, -1] + 1) % cfg.vocab)
    pert, _ = lm.forward(cfg, params, t2)
    np.testing.assert_allclose(np.asarray(base[:, :-1]),
                               np.asarray(pert[:, :-1]), atol=1e-5)


def test_mamba_state_is_sequence_length_independent():
    cfg = configs.get("mamba2_1_3b", smoke=True)
    c1 = lm.init_cache(cfg, 2, 128)
    c2 = lm.init_cache(cfg, 2, 524288)
    sz1 = sum(np.prod(l.shape) for l in jax.tree.leaves(c1))
    sz2 = sum(np.prod(l.shape) for l in jax.tree.leaves(c2))
    assert sz1 == sz2            # the long_500k cell's memory story


def test_moe_capacity_drops_tokens():
    cfg = configs.get("phi3_5_moe", smoke=True).with_(
        dtype=jnp.float32, capacity_factor=0.25)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    tight, _ = lm.forward(cfg, params, t)
    loose, _ = lm.forward(
        cfg.with_(capacity_factor=8.0), params, t)
    assert float(jnp.max(jnp.abs(tight - loose))) > 1e-6


def test_mrope_reduces_to_rope_for_text():
    """Qwen2-VL M-RoPE with t==h==w ids == plain RoPE."""
    from repro.models.common import apply_mrope, apply_rope
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 16),
                          jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 16, 3))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (2, 3, 3))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_hybrid_shared_block_fires():
    """Zamba2: zeroing the shared attention block changes outputs on
    layers where it applies."""
    cfg = configs.get("zamba2_7b", smoke=True).with_(dtype=jnp.float32)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab)
    base, _ = lm.forward(cfg, params, t)
    p2 = jax.tree.map(lambda x: x, params)
    p2["shared_attn"] = jax.tree.map(jnp.zeros_like, p2["shared_attn"])
    pert, _ = lm.forward(cfg, p2, t)
    assert float(jnp.max(jnp.abs(base - pert))) > 1e-6


def test_param_counts_full_configs():
    """Full-config parameter counts are in the advertised ballpark."""
    expect = {
        "stablelm_12b": (11e9, 14e9),
        "mistral_nemo_12b": (11e9, 14e9),
        "llama3_2_3b": (2.5e9, 4e9),
        "nemotron_4_340b": (300e9, 360e9),
        "hubert_xlarge": (0.8e9, 1.3e9),
        "phi3_5_moe": (38e9, 45e9),
        "deepseek_moe_16b": (14e9, 18e9),
        "qwen2_vl_2b": (1.2e9, 2.3e9),
        "mamba2_1_3b": (1.0e9, 1.6e9),
        "zamba2_7b": (6e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = lm.param_count(configs.get(arch))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]B"
