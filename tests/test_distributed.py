"""Distribution tests on 8 fake CPU devices — run in a subprocess so the
fake device count never leaks into the other tests' jax runtime."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns an 8-fake-device subprocess that recompiles from
# scratch — minutes of wall clock, excluded from the fast tier
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(body: str, timeout=560) -> str:
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        import numpy as np
    """) + textwrap.dedent(body)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_planner_rules():
    out = run_py("""
        from repro import configs
        from repro.runtime.sharding import Planner
        from jax.sharding import PartitionSpec as P
        mesh = jax.make_mesh((2, 4), ("data", "model"))

        cfg = configs.get("stablelm_12b")          # 32H x 160dh
        pl = Planner(mesh, cfg)
        assert pl.spec(("embed", "heads"), (5120, 5120)) == P("data", "model")
        assert pl.spec(("vocab", "embed"), (100352, 5120)) == P("model", "data")

        # llama3.2: 24 heads x 128 dh -> 24*128/4 = 768 = 6 heads OK on 4
        cfg2 = configs.get("llama3_2_3b")
        pl2 = Planner(mesh, cfg2)
        assert pl2.spec(("embed", "heads"), (3072, 3072)) == P("data", "model")
        # but a 16-way model axis cannot shard 24 heads:
        mesh16 = jax.make_mesh((1, 8), ("data", "model"))
        pl16 = Planner(mesh16, cfg2)
        # 24*128/8 = 384 = 3 heads -> fine on 8; simulate 16 via unit check
        from repro.runtime.sharding import axis_constraints
        assert axis_constraints(cfg2)["heads"] == 128

        # qwen kv=2 heads: 2*128=256; on model=4 -> 64 < 128 -> dropped
        cfg3 = configs.get("qwen2_vl_2b")
        pl3 = Planner(mesh, cfg3)
        assert pl3.spec(("embed", "kv"), (1536, 256)) == P("data", None)
        print("PLANNER_OK")
    """)
    assert "PLANNER_OK" in out


def test_train_step_parallel_matches_single_device():
    """pjit train step on a 2x4 mesh computes the same loss/params as the
    same step on a 1x1 mesh (numerical determinism of the distribution)."""
    out = run_py("""
        from repro import configs
        from repro.models import lm
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.runtime.sharding import Planner
        from repro.runtime.step import make_train_fn
        from repro.runtime.meshctx import use_mesh
        from repro.data import SyntheticCorpus

        cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
        acfg = AdamWConfig(lr=1e-3)
        params, axes = lm.init(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, acfg)
        corpus = SyntheticCorpus(cfg.vocab, seed=0)
        b = corpus.batch(0, 8, 64)
        batch = {k: jnp.asarray(v) for k, v in b.items()}

        results = {}
        for name, shape in [("single", (1, 1)), ("mesh", (2, 4))]:
            mesh = jax.make_mesh(shape, ("data", "model"))
            pl = Planner(mesh, cfg)
            p_sh = pl.tree_shardings(axes, params)
            p = jax.device_put(params, p_sh)
            o = jax.device_put(opt, pl.tree_shardings(
                type(opt)(axes, axes, ()), opt))
            with use_mesh(mesh):
                fn = jax.jit(make_train_fn(cfg, acfg, pl, microbatches=2,
                                           remat="nothing"))
                p2, o2, m = fn(p, o, batch)
            results[name] = (float(m["loss"]),
                             np.asarray(jax.device_get(
                                 p2["final_norm"])).copy())
        l1, fn1 = results["single"]
        l2, fn2 = results["mesh"]
        assert abs(l1 - l2) / abs(l1) < 1e-4, (l1, l2)
        np.testing.assert_allclose(fn1, fn2, rtol=1e-4, atol=1e-5)
        print("PARALLEL_MATCH_OK", l1)
    """)
    assert "PARALLEL_MATCH_OK" in out


def test_compressed_ddp_step_runs_and_learns():
    out = run_py("""
        from repro import configs
        from repro.models import lm
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.runtime import ddp
        from repro.data import SyntheticCorpus

        cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
        acfg = AdamWConfig(lr=3e-3, warmup_steps=1)
        mesh = jax.make_mesh((8,), ("data",))
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        opt = adamw_init(params, acfg)
        err = ddp.init_error_buffers(params)
        step = ddp.build_compressed_ddp_step(cfg, acfg, mesh)
        corpus = SyntheticCorpus(cfg.vocab, seed=0)
        losses = []
        for s in range(8):
            b = corpus.batch(s, 16, 64)
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            params, opt, err, m = step(params, opt, err, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0], losses
        # error feedback buffers are being used (non-zero)
        e0 = float(jnp.max(jnp.abs(jax.tree.leaves(err)[0])))
        assert e0 > 0
        print("DDP_OK", losses[0], losses[-1])
    """)
    assert "DDP_OK" in out


def test_compressed_vs_uncompressed_ddp_close():
    out = run_py("""
        from repro import configs
        from repro.models import lm
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.runtime import ddp
        from repro.data import SyntheticCorpus

        cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
        acfg = AdamWConfig(lr=1e-3, warmup_steps=1)
        mesh = jax.make_mesh((8,), ("data",))
        corpus = SyntheticCorpus(cfg.vocab, seed=0)

        outs = {}
        for compress in (True, False):
            params, _ = lm.init(cfg, jax.random.PRNGKey(0))
            opt = adamw_init(params, acfg)
            err = ddp.init_error_buffers(params)
            step = ddp.build_compressed_ddp_step(cfg, acfg, mesh,
                                                 compress=compress)
            for s in range(4):
                b = corpus.batch(s, 16, 64)
                batch = {k: jnp.asarray(v) for k, v in b.items()}
                params, opt, err, m = step(params, opt, err, batch)
            outs[compress] = float(m["loss"])
        # int8 EF tracks the exact all-reduce closely
        assert abs(outs[True] - outs[False]) / abs(outs[False]) < 0.05
        print("EF_CLOSE_OK", outs)
    """)
    assert "EF_CLOSE_OK" in out


def test_elastic_restore_across_meshes():
    out = run_py("""
        import tempfile
        from repro import configs
        from repro.models import lm
        from repro.optim.adamw import AdamWConfig, adamw_init
        from repro.checkpoint import CheckpointManager
        from repro.runtime.elastic import elastic_restore
        from repro.runtime.sharding import Planner

        cfg = configs.get("llama2_7b", smoke=True).with_(dtype=jnp.float32)
        acfg = AdamWConfig()
        mesh_a = jax.make_mesh((4, 2), ("data", "model"))
        mesh_b = jax.make_mesh((2, 2), ("data", "model"))  # "shrunk" job

        params, axes = lm.init(cfg, jax.random.PRNGKey(0))
        pl_a = Planner(mesh_a, cfg)
        params_a = jax.device_put(params, pl_a.tree_shardings(axes, params))
        opt_a = adamw_init(params_a, acfg)

        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_write=False)
            mgr.save(7, {"params": params_a, "opt": opt_a})
            state = elastic_restore(mgr, cfg, acfg, mesh_b)
            # bitwise identical content on the new mesh
            for a, b in zip(jax.tree.leaves(params_a),
                            jax.tree.leaves(state["params"])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            # and the restored arrays are actually sharded for mesh_b
            sh = state["params"]["final_norm"].sharding
            assert sh.mesh.shape["data"] == 2
        print("ELASTIC_OK")
    """)
    assert "ELASTIC_OK" in out


def test_packed_planner_specs_on_mesh():
    """Per-variant PartitionSpecs on a real (2,4) mesh: every d_out-
    leading plane row-shards on "model", v replicates, u only shards at
    the rank threshold, and device_put actually places the leaves."""
    out = run_py("""
        from repro import configs
        from repro.core.packed_model import (LR_SHARD_RANK,
                                             PACKED_VARIANTS,
                                             merge_packed_axes,
                                             packed_axes)
        from repro.models import lm
        from repro.runtime.sharding import Planner
        from jax.sharding import PartitionSpec as P
        from benchmarks.common import synthetic_pruned_packed

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = configs.get("stablelm_12b", smoke=True).with_(
            dtype=jnp.float32, n_layers=4)
        _, packed, rep = synthetic_pruned_packed(
            cfg, lambda l: 0.25 if l < 2 else 0.5,
            skip={(0, "attn.wq")})
        pl = Planner(mesh, cfg)
        axes = merge_packed_axes(lm.param_axes(cfg), packed)
        specs = pl.tree_specs(axes, packed)
        wq = specs["layers"]["attn"]["wq"]
        for g in wq.groups:
            assert g.sparse_vals == P(None, "model", None), g.sparse_vals
            assert g.sparse_idx == P(None, "model", None)
        assert wq.dense == P(None, None, "model")

        placed = jax.device_put(packed, pl.tree_shardings(axes, packed))
        born = placed["layers"]["attn"]["wq"].groups[0].sparse_vals
        assert born.sharding.spec == P(None, "model", None), born.sharding
        assert len(born.sharding.device_set) == 8
        print("PACKED_SPECS_OK", sorted(rep.by_variant))
    """)
    assert "PACKED_SPECS_OK" in out


def test_packed_vs_dense_decode_parity_on_mesh():
    """End-to-end: a mixed ELL / N:M / low-rank plan through the real
    compression pipeline, packed leaves born sharded on a (2,4) mesh,
    multi-step decode matches the dense-equivalent weights on one
    device."""
    out = run_py("""
        from repro import configs
        from repro.core.packed_model import merge_packed_axes, pack_plan_decs
        from repro.core.pipeline import compress_model
        from repro.core.plan import CompressionPlan
        from repro.core.slab import SLaBConfig
        from repro.data import calibration_batch
        from repro.models import lm
        from repro.models.common import positions_for
        from repro.runtime.meshctx import use_mesh
        from repro.runtime.sharding import Planner

        cfg = configs.get("stablelm_12b", smoke=True).with_(
            dtype=jnp.float32)
        params, axes = lm.init(cfg, jax.random.PRNGKey(0))
        cal = calibration_batch(cfg.vocab, n_seq=2, seq_len=32)
        plan = CompressionPlan.parse(
            "attn.wo=wanda; attn.wq=sparsegpt@pattern=2:4; "
            "mlp.w_gate=hassle@rank=4; *=slab",
            base=SLaBConfig(cr=0.5, iters=2))
        dense_c, stats, decs = compress_model(cfg, params, cal, plan=plan,
                                              keep_decompositions=True)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pl = Planner(mesh, cfg)
        dense_sh = jax.device_put(dense_c, pl.tree_shardings(axes, dense_c))
        packed, rep = pack_plan_decs(
            dense_sh, decs, cfg.n_layers, plan, dtype=cfg.dtype,
            variants={(s.layer, s.name): s.variant for s in stats},
            planner=pl)
        assert rep.n_packed > 0 and not rep.fallback, rep
        variants = set(rep.by_variant)
        assert any(v.endswith("-ell") for v in variants), variants
        assert any(v.endswith("-nm") for v in variants), variants
        wq0 = packed["layers"]["attn"]["wq"]
        leaf = jax.tree.leaves(wq0, is_leaf=lambda x: hasattr(x, "sharding"))
        assert len({s for l in leaf
                    for s in [len(l.sharding.device_set)]}) >= 1

        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0,
                                  cfg.vocab)
        def dec(p, m):
            with use_mesh(m):
                cache = lm.init_cache(cfg, 2, 4)
                step = jax.jit(lambda c, t, po: lm.decode_step(
                    cfg, p, c, t, po))
                for t in range(4):
                    logits, cache = step(
                        cache, toks[:, t:t+1],
                        positions_for(cfg, 2, 1, offset=t))
            return np.asarray(jax.device_get(logits))

        l_mesh = dec(packed, mesh)
        l_dense = dec(dense_c, None)
        np.testing.assert_allclose(l_mesh, l_dense, rtol=1e-3, atol=1e-3)
        print("PACKED_MESH_PARITY_OK", sorted(variants))
    """)
    assert "PACKED_MESH_PARITY_OK" in out


def test_packed_degraded_replication():
    """A d_out the model axis can't divide (d_ff=250 on model=4)
    replicates that path's planes — degraded but correct — while
    divisible paths still shard; decode parity holds."""
    out = run_py("""
        from repro import configs
        from repro.core.packed_model import (PackedStack,
                                             merge_packed_axes)
        from repro.models import lm
        from repro.models.common import positions_for
        from repro.runtime.meshctx import use_mesh
        from repro.runtime.sharding import Planner
        from jax.sharding import PartitionSpec as P
        from benchmarks.common import synthetic_pruned_packed

        cfg = configs.get("stablelm_12b", smoke=True).with_(
            dtype=jnp.float32, d_ff=250)
        _, packed, _ = synthetic_pruned_packed(cfg, lambda l: 0.5)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        pl = Planner(mesh, cfg)
        axes = merge_packed_axes(lm.param_axes(cfg), packed)
        specs = pl.tree_specs(axes, packed)

        def vals(node):
            gs = node.groups if isinstance(node, PackedStack) else (node,)
            return [g.sparse_vals for g in gs]
        for s in vals(specs["layers"]["mlp"]["w_gate"]):
            assert s == P(None, None, None), s      # 250 % 4 -> replicate
        for s in vals(specs["layers"]["attn"]["wq"]):
            assert s == P(None, "model", None), s   # 128 % 4 -> shard

        placed = jax.device_put(packed, pl.tree_shardings(axes, packed))
        toks = jax.random.randint(jax.random.PRNGKey(2), (2, 2), 0,
                                  cfg.vocab)
        def dec(p, m):
            with use_mesh(m):
                cache = lm.init_cache(cfg, 2, 2)
                step = jax.jit(lambda c, t, po: lm.decode_step(
                    cfg, p, c, t, po))
                for t in range(2):
                    logits, cache = step(
                        cache, toks[:, t:t+1],
                        positions_for(cfg, 2, 1, offset=t))
            return np.asarray(jax.device_get(logits))
        np.testing.assert_allclose(dec(placed, mesh), dec(packed, None),
                                   rtol=2e-4, atol=2e-4)
        print("DEGRADED_REPLICATION_OK")
    """)
    assert "DEGRADED_REPLICATION_OK" in out


def test_dryrun_cell_subprocess_smoke():
    """A miniature multi-pod dry-run: 2x2x2 mesh, reduced config, real
    lower+compile+analysis through the launch.cell machinery."""
    out = run_py("""
        from repro import configs
        from repro.launch import cell as cell_lib
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        cfg = configs.get("llama2_7b", smoke=True)
        shape = configs.ShapeSpec("train_4k", "train", 128, 8)
        res = cell_lib.run_cell("llama2_7b", "train_4k", mesh, "mini-multi",
                                cfg_override=cfg, shape_override=shape)
        assert res.ok, res.error
        assert res.hlo_flops > 0 and res.collectives["total"]["count"] > 0
        print("DRYRUN_SMOKE_OK", res.microbatches)
    """)
    assert "DRYRUN_SMOKE_OK" in out
